"""Serving benchmark — the deploy-side latency/throughput trajectory, next
to ``engine_bench`` (training) and ``roofline`` (kernels).

Two sweeps per model family (densenet-mini, unet-mini):

  * **batch sweep** (closed loop): ``BucketScorer.score`` timed directly
    at every ladder bucket — per-dispatch p50/p99 wall-clock and images/s
    at that batch size.  This is the service's intrinsic latency ladder:
    what one padded-bucket dispatch costs once compilation is out of the
    hot path.
  * **arrival sweep** (open loop): single-image requests submitted to a
    live ``ScreeningService`` at fixed arrival rates; per-request total
    latency p50/p99, achieved throughput, and the mean coalesced batch
    size.  This measures what the QUEUE adds on top of the ladder — the
    batching/max-wait tradeoff under load.

Steady-state serving must issue ZERO fresh compiles: every timed section
asserts ``BucketScorer.n_compiles`` is frozen at its construction count
(the ladder is pre-lowered; any drift is a bug, and the bench exits 1).

Writes ``benchmarks/results/BENCH_serving.json``:

    {"families": ["densenet", "unet"],
     "batch_sweep": [{"family", "bucket", "p50_ms", "p99_ms",
                      "images_per_sec"}, ...],
     "arrival_sweep": [{"family", "rate_rps", "p50_ms", "p99_ms",
                        "throughput_rps", "batch_n_mean"}, ...],
     "n_compiles": {"densenet": 7, ...}}

``--trace PATH`` additionally writes the arrival sweep's merged request
trace (queue-wait / pad / dispatch / readback spans per request batch, one
``repro.obs`` lane per family) as Chrome-trace JSON — the artifact the CI
slow job uploads.

``--check-against BENCH.json`` gates p99 latency: a batch-sweep or
arrival-sweep p99 more than 20% above the committed value (plus a 1 ms
absolute slack floor — sub-ms buckets jitter more than 20% on shared CPU
runners) fails with exit 1, mirroring the engine bench's speedup gate.

  PYTHONPATH=src python -m benchmarks.serving_bench [--smoke]
      [--families densenet,unet] [--buckets 1,2,4,8,16,32,64]
      [--rates 20,50,100] [--reps N] [--check-against PATH] [--trace PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

from repro import optim as O
from repro.core.partition import cnn_adapter
from repro.core.strategies import make_strategy
from repro.data.synthetic import make_cxr_clients
from repro.models.cnn import (DenseNetConfig, UNetConfig, build_densenet,
                              build_unet)
from repro.obs.trace import PID_SERVING, merge_events, write_chrome_trace
from repro.serving import BucketScorer, ScreeningService

OUT = os.path.join(os.path.dirname(__file__), "results",
                   "BENCH_serving.json")

FAMILIES = {
    # mini configs: the bench measures the serving machinery (pad,
    # dispatch, queue) at CI scale, not full DenseNet-121 conv throughput
    "densenet": lambda: build_densenet(
        DenseNetConfig(growth=4, blocks=(1, 2), stem_ch=8, cut_layer=1)),
    "unet": lambda: build_unet(
        UNetConfig(widths=(8, 16), cut_layer=1)),
}


def trained_servable(family: str, image_size: int):
    """One FL round over tiny synthetic hospitals -> the family's export
    (the bench serves a REAL trained artifact, not random params)."""
    clients = make_cxr_clients(seed=0, n_clients=3, train_per_client=8,
                               val_per_client=4, test_per_client=8,
                               image_size=image_size)
    adapter = cnn_adapter(FAMILIES[family]())
    strat = make_strategy("fl", adapter, lambda: O.adam(1e-3), len(clients))
    state = strat.setup(jax.random.key(0))
    state, _ = strat.run_epoch(state, [c.train for c in clients],
                               np.random.default_rng(0), 4)
    img = clients[0].test["image"]
    return strat.export(state, meta={"bench": family}), img


def pctl(xs, q):
    return float(np.percentile(np.asarray(xs, np.float64), q))


def batch_sweep(family, scorer, images, reps):
    """Closed-loop per-bucket dispatch latency (one warm pass, then
    ``reps`` timed calls per bucket)."""
    rows = []
    rng = np.random.default_rng(0)
    pool = images[rng.integers(0, len(images),
                               size=max(scorer.buckets[-1], len(images)))]
    for b in scorer.buckets:
        batch = {"image": pool[:b]}
        scorer.score(batch)                       # warm (still no compile)
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            scorer.score(batch)
            times.append(time.perf_counter() - t0)
        p50, p99 = pctl(times, 50), pctl(times, 99)
        rows.append({"family": family, "bucket": b,
                     "p50_ms": round(p50 * 1e3, 3),
                     "p99_ms": round(p99 * 1e3, 3),
                     "images_per_sec": round(b / max(p50, 1e-9), 1)})
        print(f"{family:10s} bucket={b:3d}  p50 {p50 * 1e3:7.2f} ms  "
              f"p99 {p99 * 1e3:7.2f} ms  {rows[-1]['images_per_sec']:9.1f} "
              "img/s")
    return rows


def arrival_sweep(family, servable, images, rates, duration_s, max_wait_s):
    """Open-loop fixed-rate arrivals through a live ScreeningService; each
    rate gets a fresh service so queue state never bleeds across rates.
    Returns (rows, trace_events) — the LAST rate's request trace."""
    rows, events = [], []
    for rate in rates:
        with ScreeningService(servable, image_shape=images.shape[1:],
                              precision="fp32", max_wait_s=max_wait_s,
                              max_queue=4096, trace=True) as svc:
            svc.score_one({"image": images[0]})   # warm the service path
            built = svc.scorer.n_compiles
            n = max(int(rate * duration_s), 8)
            period = 1.0 / rate
            reqs = []
            t_start = time.perf_counter()
            for i in range(n):
                target = t_start + i * period
                while time.perf_counter() < target:
                    pass                          # open-loop pacing
                reqs.append(svc.submit({"image": images[i % len(images)]}))
            for r in reqs:
                r.done.wait(60)
            elapsed = time.perf_counter() - t_start
            assert svc.scorer.n_compiles == built, \
                f"{family}: fresh compile during steady-state serving"
            lats = [r.lat["total_s"] for r in reqs]
            row = {"family": family, "rate_rps": rate,
                   "p50_ms": round(pctl(lats, 50) * 1e3, 3),
                   "p99_ms": round(pctl(lats, 99) * 1e3, 3),
                   "throughput_rps": round(n / elapsed, 1),
                   "batch_n_mean": round(svc.stats()["batch_n_mean"], 2)}
            rows.append(row)
            print(f"{family:10s} rate={rate:5g}/s  p50 {row['p50_ms']:7.2f}"
                  f" ms  p99 {row['p99_ms']:7.2f} ms  "
                  f"{row['throughput_rps']:7.1f} req/s  "
                  f"batch {row['batch_n_mean']:5.2f}")
            events = svc.trace_events()
    return rows, events


def check_against(baseline_path: str, fresh: dict,
                  max_regression: float = 0.2,
                  abs_slack_ms: float = 1.0) -> list[str]:
    """Gate p99 latency per (sweep, family, point) against a committed
    baseline: fail when fresh p99 exceeds committed * (1 + regression)
    + slack.  The absolute slack keeps sub-millisecond buckets from
    gating scheduler jitter."""
    with open(baseline_path) as f:
        committed = json.load(f)
    failures = []
    for sweep, keyf in (("batch_sweep", lambda r: r["bucket"]),
                        ("arrival_sweep", lambda r: r["rate_rps"])):
        base = {(r["family"], keyf(r)): r["p99_ms"]
                for r in committed.get(sweep, [])}
        for r in fresh.get(sweep, []):
            key = (r["family"], keyf(r))
            old = base.get(key)
            if old is None:
                continue
            ceil = old * (1.0 + max_regression) + abs_slack_ms
            status = "OK" if r["p99_ms"] <= ceil else "REGRESSED"
            print(f"  gate {sweep}:{key[0]}@{key[1]:<4g} committed "
                  f"{old:8.2f} ms  now {r['p99_ms']:8.2f} ms "
                  f"(ceil {ceil:.2f})  {status}")
            if r["p99_ms"] > ceil:
                failures.append(f"{sweep}:{key[0]}@{key[1]}")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (small ladder, short load)")
    ap.add_argument("--families", default=None)
    ap.add_argument("--buckets", default=None)
    ap.add_argument("--rates", default=None,
                    help="arrival rates (requests/s), comma-separated")
    ap.add_argument("--reps", type=int, default=None,
                    help="timed dispatches per bucket (batch sweep)")
    ap.add_argument("--duration", type=float, default=None,
                    help="seconds of load per arrival rate")
    ap.add_argument("--image-size", type=int, default=16)
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--out", default=OUT)
    ap.add_argument("--trace", default=None,
                    help="write the merged request trace (Chrome JSON)")
    ap.add_argument("--check-against", default=None,
                    help="committed BENCH_serving.json to gate p99 "
                         "against (fail on >20%% + 1 ms regression)")
    args = ap.parse_args()

    families = (args.families.split(",") if args.families
                else list(FAMILIES))
    buckets = ([int(x) for x in args.buckets.split(",")] if args.buckets
               else ([1, 2, 4, 8] if args.smoke else [1, 2, 4, 8, 16, 32,
                                                      64]))
    rates = ([float(x) for x in args.rates.split(",")] if args.rates
             else ([50, 200] if args.smoke else [20, 50, 100, 200]))
    reps = args.reps or (20 if args.smoke else 100)
    duration = args.duration or (0.5 if args.smoke else 2.0)

    out = {"device": jax.devices()[0].device_kind,
           "families": families, "buckets": buckets, "rates": rates,
           "max_wait_ms": args.max_wait_ms,
           "batch_sweep": [], "arrival_sweep": [], "n_compiles": {}}
    all_traces = []
    for fi, family in enumerate(families):
        servable, images = trained_servable(family, args.image_size)
        scorer = BucketScorer(servable, image_shape=images.shape[1:],
                              buckets=buckets)
        built = scorer.n_compiles
        out["batch_sweep"] += batch_sweep(family, scorer, images, reps)
        assert scorer.n_compiles == built, \
            f"{family}: fresh compile during the timed batch sweep"
        out["n_compiles"][family] = built
        rows, events = arrival_sweep(family, servable, images, rates,
                                     duration, args.max_wait_ms * 1e-3)
        out["arrival_sweep"] += rows
        # one serving lane per family in the merged trace
        all_traces.append(merge_events(events,
                                       pid_offset=fi * 10 + PID_SERVING))

    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")
    if args.trace:
        write_chrome_trace(merge_events(*all_traces), args.trace)
        print(f"wrote {args.trace}")

    if args.check_against:
        failures = check_against(args.check_against, out)
        if failures:
            print(f"FAIL: p99 latency regressed >20% (+1 ms slack) vs "
                  f"committed baseline for {failures}")
            sys.exit(1)
        print("serving p99 gate OK (within 20% + 1 ms of committed "
              "baseline)")


if __name__ == "__main__":
    main()
