"""Engine benchmark — stepwise (host-loop) vs compiled (scan/vmap) epochs.

For each method and hospital count, trains the same synthetic CXR task with
both execution engines and reports steps/sec and epoch wall-clock (median
over timed epochs, compile/warm-up epoch excluded for BOTH engines — the
comparison is steady-state dispatch cost, which is what dominates the
many-hospital sweeps in ROADMAP's production target).

Writes ``benchmarks/results/BENCH_engine.json``:

    {"results": [{"method", "n_clients", "engine", "steps_per_epoch",
                  "epoch_seconds", "steps_per_sec"}, ...],
     "speedup": {"fl@10": 7.3, ...}}   # compiled / stepwise steps/sec

  PYTHONPATH=src python -m benchmarks.engine_bench [--smoke]
      [--methods fl,sl_am,sflv3_ac] [--clients 3,10,50] [--epochs N]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro import optim as O
from repro.core.partition import cnn_adapter
from repro.core.strategies import make_strategy
from repro.data.synthetic import make_cxr_clients
from repro.models.cnn import DenseNetConfig, build_densenet

DEFAULT_METHODS = ["fl", "sl_am", "sflv3_ac"]
DEFAULT_CLIENTS = [3, 10, 50]
OUT = os.path.join(os.path.dirname(__file__), "results",
                   "BENCH_engine.json")


def build_setup(n_clients: int, train_per_client: int, image_size: int):
    """Dispatch-bound regime: per-step compute is kept tiny so the numbers
    isolate what the engine changes — host dispatch and hospital-axis
    parallelism — rather than conv throughput."""
    clients = make_cxr_clients(seed=0, n_clients=n_clients,
                               train_per_client=train_per_client,
                               val_per_client=8, test_per_client=8,
                               image_size=image_size)
    cfg = DenseNetConfig(growth=2, blocks=(1, 1), stem_ch=4, cut_layer=1)
    return clients, cnn_adapter(build_densenet(cfg))


def time_engine(method, engine, clients, adapter, batch_size, epochs):
    strat = make_strategy(method, adapter, lambda: O.adam(1e-3),
                          len(clients), engine=engine)
    state = strat.setup(jax.random.key(0))
    rng = np.random.default_rng(0)
    data = [c.train for c in clients]
    # warm-up epoch: tracing + compilation for both engines
    state, log = strat.run_epoch(state, data, rng, batch_size)
    times = []
    for _ in range(epochs):
        jax.block_until_ready(jax.tree.leaves(
            state.get("params", state.get("server")))[0])
        t0 = time.perf_counter()
        state, log = strat.run_epoch(state, data, rng, batch_size)
        jax.block_until_ready(jax.tree.leaves(
            state.get("params", state.get("server")))[0])
        times.append(time.perf_counter() - t0)
    sec = float(np.median(times))
    return {"method": method, "n_clients": len(clients), "engine": engine,
            "steps_per_epoch": log.steps, "epoch_seconds": sec,
            "steps_per_sec": log.steps / sec if sec > 0 else float("inf")}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI (non-blocking slow job)")
    ap.add_argument("--methods", default=None)
    ap.add_argument("--clients", default=None)
    ap.add_argument("--epochs", type=int, default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--train-per-client", type=int, default=None)
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args()

    methods = (args.methods.split(",") if args.methods
               else (["fl"] if args.smoke else DEFAULT_METHODS))
    clients_grid = ([int(x) for x in args.clients.split(",")]
                    if args.clients else ([3] if args.smoke
                                          else DEFAULT_CLIENTS))
    epochs = args.epochs or (1 if args.smoke else 2)
    tpc = args.train_per_client or (16 if args.smoke else 128)

    results, speedup = [], {}
    for n in clients_grid:
        clients, adapter = build_setup(n, tpc, image_size=8)
        for method in methods:
            row = {}
            for engine in ("stepwise", "compiled"):
                r = time_engine(method, engine, clients, adapter,
                                args.batch, epochs)
                results.append(r)
                row[engine] = r
                print(f"{method:10s} n={n:3d} {engine:9s} "
                      f"{r['steps_per_sec']:9.1f} steps/s "
                      f"({r['epoch_seconds'] * 1e3:8.1f} ms/epoch)")
            sp = (row["compiled"]["steps_per_sec"]
                  / row["stepwise"]["steps_per_sec"])
            speedup[f"{method}@{n}"] = round(sp, 2)
            print(f"{method:10s} n={n:3d} speedup   {sp:9.2f}x")

    out = {"device": jax.devices()[0].device_kind,
           "batch_size": args.batch, "train_per_client": tpc,
           "epochs_timed": epochs, "results": results, "speedup": speedup}
    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
