"""Engine benchmark — stepwise (host-loop) vs compiled (scan/vmap) epochs
and whole runs.

For each method and hospital count, trains the same synthetic CXR task
with both execution engines and reports steps/sec and epoch wall-clock
(median over timed epochs, compile/warm-up epoch excluded for BOTH
engines — the comparison is steady-state dispatch cost, which is what
dominates the many-hospital sweeps in ROADMAP's production target).  A
second column times ``Strategy.run(n_epochs=RUN_EPOCHS)`` — the compiled
engine executes the whole run as ONE XLA program, the stepwise engine as
a per-epoch loop.

A telemetry column runs each compiled whole-run twice — with and without
the full ``repro.obs.Telemetry`` tap spec — and records the steady-state
overhead the extra scan outputs cost; with ``--check-against`` the
overhead must stay under ``--max-telemetry-overhead`` (default 5%).

Writes ``benchmarks/results/BENCH_engine.json``:

    {"results": [{"method", "n_clients", "engine", "mode",
                  "steps_per_epoch", "epoch_seconds", "steps_per_sec"},
                 ...],                  # run3 rows add compile_seconds,
                                        # dispatches_per_run, observed,
                                        # precision, fused (+ the raw-speed
                                        # rows: hlo_compile_seconds and the
                                        # compiler's "memory" buffer view)
     "speedup": {"fl@10": 7.3,          # compiled / stepwise, one epoch
                 "fl@10:run3": 9.1,     # whole 3-epoch run
                 "sl_am@10:fused": 1.4, # fused kernel  vs fp32-unfused
                 "sl_am@10:bf16": 1.6}, # bf16 compute  vs fp32-unfused
     "telemetry_overhead": {"fl@10": 0.012}}

A raw-speed grid (compiled whole-run only) times each method at
(fp32, unfused int8 transport) -> (fp32, fused cut-layer kernel) ->
(bf16, fused); the ``:fused`` / ``:bf16`` keys are steps/s ratios over
the fp32-unfused oracle and gate under ``--check-against`` exactly like
the engine speedups — a drop below 80% of the committed floor fails.
The variants are timed ROUND-ROBIN (one run each per pass, median per
variant — ``time_raw_grid``): shared hosts drift the SAME program ±30%
with position in the process, so sequential per-variant timing measures
schedule, not code.  On a CPU runner the fused and unfused programs
compile to the SAME optimized HLO (interpret-mode Pallas re-fuses under
XLA), so the CPU ratio is ~1.0 and its gate is a pure no-regression
guard; the kernel's single-VMEM-pass saving is real hardware's story.
bf16 on CPU runs through f32 converts and may sit slightly under 1.0 —
also honest.

``--shard`` additionally times the compiled engine with
``make_strategy(..., shard=True)`` — the hospital axis placed on the
``core.placement`` "hosp" mesh (pad-to-mesh phantom hospitals when the
count does not divide the device count) — recorded as ``:shard`` rows
and speedup keys.  Run it under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` or on a real
multi-device host; on one device shard=True is a no-op and the column
just duplicates the compiled numbers.

``--participation`` adds the cross-device column: fl compiled whole-runs
with ``Participation(n_global=N, k=10)`` at N in {100, 1000} — fixed-K
cohorts packed into a K-slot axis, so steps/s should stay roughly flat
in N (the ``fl@1000:part_scale`` speedup key records the N=1000/N=100
ratio).  ``--participation-only`` runs just this column and merges its
rows into an existing ``--out`` JSON (the full grid is expensive; the
participation column can be refreshed alone).

``--check-against BENCH.json`` re-reads a committed baseline and FAILS
(exit 1) if any matching compiled-vs-stepwise speedup regressed by more
than 20%.  Speedups are regime-sensitive (steps per epoch change how far
dispatch overhead is amortized), so gate like against like: the slow CI
job runs the smoke grid against the committed
``benchmarks/results/BENCH_engine_smoke.json``, and the multi-device job
runs ``--smoke --shard`` against
``benchmarks/results/BENCH_engine_smoke_shard.json``.

  PYTHONPATH=src python -m benchmarks.engine_bench [--smoke] [--shard]
      [--methods fl,sl_am,sflv3_ac] [--clients 3,10,50] [--epochs N]
      [--run-epochs 3] [--check-against PATH]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

from repro import optim as O
from repro.core.partition import cnn_adapter
from repro.core.strategies import make_strategy
from repro.data.synthetic import make_cxr_clients
from repro.models.cnn import DenseNetConfig, build_densenet

DEFAULT_METHODS = ["fl", "sl_am", "sflv3_ac"]
DEFAULT_CLIENTS = [3, 10, 50]
OUT = os.path.join(os.path.dirname(__file__), "results",
                   "BENCH_engine.json")


def build_setup(n_clients: int, train_per_client: int, image_size: int):
    """Dispatch-bound regime: per-step compute is kept tiny so the numbers
    isolate what the engine changes — host dispatch and hospital-axis
    parallelism — rather than conv throughput."""
    clients = make_cxr_clients(seed=0, n_clients=n_clients,
                               train_per_client=train_per_client,
                               val_per_client=8, test_per_client=8,
                               image_size=image_size)
    cfg = DenseNetConfig(growth=2, blocks=(1, 1), stem_ch=4, cut_layer=1)
    return clients, cnn_adapter(build_densenet(cfg))


def time_engine(method, engine, clients, adapter, batch_size, epochs,
                shard=False):
    strat = make_strategy(method, adapter, lambda: O.adam(1e-3),
                          len(clients), engine=engine, shard=shard)
    state = strat.setup(jax.random.key(0))
    rng = np.random.default_rng(0)
    data = [c.train for c in clients]
    # warm-up epoch: tracing + compilation for both engines
    state, log = strat.run_epoch(state, data, rng, batch_size)
    times = []
    for _ in range(epochs):
        jax.block_until_ready(jax.tree.leaves(
            state.get("params", state.get("server")))[0])
        t0 = time.perf_counter()
        state, log = strat.run_epoch(state, data, rng, batch_size)
        jax.block_until_ready(jax.tree.leaves(
            state.get("params", state.get("server")))[0])
        times.append(time.perf_counter() - t0)
    sec = float(np.median(times))
    return {"method": method, "n_clients": len(clients), "engine": engine,
            "mode": "epoch", "shard": bool(shard),
            "steps_per_epoch": log.steps, "epoch_seconds": sec,
            "steps_per_sec": log.steps / sec if sec > 0 else float("inf")}


def time_whole_run(method, engine, clients, adapter, batch_size,
                   run_epochs, reps, shard=False, observe=False,
                   precision="fp32", fuse=None, cost=False):
    """Time ``Strategy.run(n_epochs=run_epochs)`` — ONE program under the
    compiled engine, a per-epoch loop under stepwise.  ``observe=True``
    runs with the full telemetry spec (repro.obs) — the taps ride the
    run scan as extra outputs, so the steady-state cost they add is what
    the telemetry-overhead gate measures.  ``fuse`` (raw-speed grid) adds
    an int8 cut-layer transport with the fused kernel on or off;
    ``precision`` selects bf16 compute; ``cost=True`` re-lowers the
    compiled run for the compiler's peak-HBM view (``obs.profile``)."""
    from repro.obs import Telemetry
    transport = None
    if fuse is not None:
        from repro.wire import Transport
        transport = Transport("int8", fuse=fuse)
    strat = make_strategy(method, adapter, lambda: O.adam(1e-3),
                          len(clients), engine=engine, shard=shard,
                          transport=transport, precision=precision,
                          observe=Telemetry() if observe else None)
    state = strat.setup(jax.random.key(0))
    rng = np.random.default_rng(0)
    data = [c.train for c in clients]
    t0 = time.perf_counter()
    state, logs = strat.run(state, data, rng, batch_size, run_epochs)
    first_call = time.perf_counter() - t0        # trace+compile dominated
    times = []
    for _ in range(reps):
        jax.block_until_ready(jax.tree.leaves(
            state.get("params", state.get("server")))[0])
        t0 = time.perf_counter()
        state, logs = strat.run(state, data, rng, batch_size, run_epochs)
        jax.block_until_ready(jax.tree.leaves(
            state.get("params", state.get("server")))[0])
        times.append(time.perf_counter() - t0)
    sec = float(np.median(times))
    steps = sum(l.steps for l in logs)
    row = {"method": method, "n_clients": len(clients), "engine": engine,
           "mode": f"run{run_epochs}" + (":obs" if observe else ""),
           "shard": bool(shard), "observed": bool(observe),
           "precision": precision,
           "fused": None if fuse is None else bool(fuse),
           "steps_per_epoch": steps, "epoch_seconds": sec,
           "compile_seconds": first_call - sec,
           "dispatches_per_run": strat._dispatches // (reps + 1),
           "steps_per_sec": steps / sec if sec > 0 else float("inf")}
    if cost:
        from repro.obs.profile import hlo_cost
        hc = hlo_cost(strat)
        if hc is not None:
            row["hlo_compile_seconds"] = hc["compile_seconds"]
            if "memory" in hc:
                row["memory"] = hc["memory"]
    return row


def time_raw_grid(method, clients, adapter, batch_size, run_epochs, reps,
                  variants):
    """Round-robin timing of the raw-speed variants — one timed run per
    variant per pass.  Sequential per-variant timing is unusable for a
    ratio gate on shared hosts: the SAME program drifts ±30% with
    position in the process (allocator growth, cgroup throttle phases),
    so whichever variant runs later eats the drift.  Interleaving spreads
    it across all variants; the per-variant median over passes is fair."""
    from repro.obs.profile import hlo_cost
    data = [c.train for c in clients]
    recs = []
    for prec, fu in variants:
        transport = None
        if fu is not None:
            from repro.wire import Transport
            transport = Transport("int8", fuse=fu)
        strat = make_strategy(method, adapter, lambda: O.adam(1e-3),
                              len(clients), transport=transport,
                              precision=prec)
        state = strat.setup(jax.random.key(0))
        rng = np.random.default_rng(0)
        t0 = time.perf_counter()
        state, logs = strat.run(state, data, rng, batch_size, run_epochs)
        recs.append({"prec": prec, "fu": fu, "strat": strat, "state": state,
                     "rng": rng, "logs": logs,
                     "first": time.perf_counter() - t0, "times": []})
    for _ in range(reps):
        for rec in recs:
            s = rec["state"]
            jax.block_until_ready(jax.tree.leaves(
                s.get("params", s.get("server")))[0])
            t0 = time.perf_counter()
            rec["state"], rec["logs"] = rec["strat"].run(
                rec["state"], data, rec["rng"], batch_size, run_epochs)
            jax.block_until_ready(jax.tree.leaves(
                rec["state"].get("params", rec["state"].get("server")))[0])
            rec["times"].append(time.perf_counter() - t0)
    rows = []
    for rec in recs:
        sec = float(np.median(rec["times"]))
        steps = sum(l.steps for l in rec["logs"])
        row = {"method": method, "n_clients": len(clients),
               "engine": "compiled", "mode": f"run{run_epochs}",
               "shard": False, "observed": False,
               "precision": rec["prec"],
               "fused": None if rec["fu"] is None else bool(rec["fu"]),
               "steps_per_epoch": steps, "epoch_seconds": sec,
               "compile_seconds": rec["first"] - sec,
               "dispatches_per_run": rec["strat"]._dispatches // (reps + 1),
               "steps_per_sec": steps / sec if sec > 0 else float("inf")}
        hc = hlo_cost(rec["strat"])
        if hc is not None:
            row["hlo_compile_seconds"] = hc["compile_seconds"]
            if "memory" in hc:
                row["memory"] = hc["memory"]
        rows.append(row)
    return rows


PART_CLIENTS = [100, 1000]
PART_K = 10


def time_participation(n_global, k, batch_size, run_epochs, reps):
    """Cross-device column: fl with ``Participation(n_global, k)`` — each
    round trains a K-hospital cohort out of N enrolled.  The compiled
    run packs cohorts into a fixed K-slot axis, so steps/s should be
    roughly FLAT in N (compute scales with K; only host-side packing
    and data bookkeeping grow with N) — the ``:part_scale`` speedup key
    gates that ratio.  Tiny per-hospital data keeps the N=1000 setup
    affordable on CPU."""
    from repro.core.participation import Participation
    clients, adapter = build_setup(n_global, 16, image_size=8)
    strat = make_strategy("fl", adapter, lambda: O.adam(1e-3), n_global,
                          participation=Participation(n_global=n_global,
                                                      k=k))
    state = strat.setup(jax.random.key(0))
    rng = np.random.default_rng(0)
    data = [c.train for c in clients]
    t0 = time.perf_counter()
    state, logs = strat.run(state, data, rng, batch_size, run_epochs)
    first_call = time.perf_counter() - t0
    times = []
    for _ in range(reps):
        jax.block_until_ready(jax.tree.leaves(state["params"])[0])
        t0 = time.perf_counter()
        state, logs = strat.run(state, data, rng, batch_size, run_epochs)
        jax.block_until_ready(jax.tree.leaves(state["params"])[0])
        times.append(time.perf_counter() - t0)
    sec = float(np.median(times))
    steps = sum(l.steps for l in logs)
    return {"method": "fl", "n_clients": n_global, "engine": "compiled",
            "mode": f"run{run_epochs}:part", "shard": False,
            "participation_k": k, "steps_per_epoch": steps,
            "epoch_seconds": sec, "compile_seconds": first_call - sec,
            "dispatches_per_run": strat._dispatches // (reps + 1),
            "steps_per_sec": steps / sec if sec > 0 else float("inf")}


def run_participation_grid(batch_size, run_epochs, reps):
    rows, part = [], {}
    for n in PART_CLIENTS:
        r = time_participation(n, PART_K, batch_size, run_epochs, reps)
        rows.append(r)
        part[f"fl@{n}:k{PART_K}"] = round(r["steps_per_sec"], 1)
        print(f"{'fl':10s} n={n:4d} part(K={PART_K})    "
              f"run{run_epochs:<3d} {r['steps_per_sec']:9.1f} steps/s "
              f"({r['epoch_seconds'] * 1e3:8.1f} ms, "
              f"{r['dispatches_per_run']} dispatch/run)")
    lo, hi = PART_CLIENTS[0], PART_CLIENTS[-1]
    scale = (part[f"fl@{hi}:k{PART_K}"]
             / max(part[f"fl@{lo}:k{PART_K}"], 1e-9))
    print(f"{'fl':10s} part scaling N={hi} vs N={lo}: {scale:5.2f}x "
          "(fixed-K cohorts: ~flat in N)")
    return rows, part, round(scale, 2)


def check_telemetry_overhead(overhead: dict,
                             max_overhead: float = 0.05) -> list[str]:
    """Gate the steady-state cost of telemetry: an observed run's steps/s
    within ``max_overhead`` of the unobserved run's (the taps are extra
    scan outputs, not extra dispatches — >5% means something regressed
    into the hot path)."""
    failures = []
    for key, ov in overhead.items():
        status = "OK" if ov <= max_overhead else "REGRESSED"
        print(f"  telemetry {key:16s} overhead {ov * 100:6.2f}% "
              f"(max {max_overhead * 100:.0f}%)  {status}")
        if ov > max_overhead:
            failures.append(key)
    return failures


def check_against(baseline_path: str, speedup: dict,
                  max_regression: float = 0.2) -> list[str]:
    """Compare fresh compiled-vs-stepwise speedups to a committed
    baseline; a key regressing below ``(1 - max_regression) x`` its
    committed value is a failure."""
    with open(baseline_path) as f:
        committed = json.load(f).get("speedup", {})
    failures = []
    for key, new in speedup.items():
        old = committed.get(key)
        if old is None:
            continue
        floor = (1.0 - max_regression) * old
        status = "OK" if new >= floor else "REGRESSED"
        print(f"  gate {key:16s} committed {old:6.2f}x  now {new:6.2f}x "
              f"(floor {floor:.2f}x)  {status}")
        if new < floor:
            failures.append(key)
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI (non-blocking slow job)")
    ap.add_argument("--methods", default=None)
    ap.add_argument("--clients", default=None)
    ap.add_argument("--epochs", type=int, default=None)
    ap.add_argument("--run-epochs", type=int, default=3,
                    help="whole-run column: epochs per Strategy.run call")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--train-per-client", type=int, default=None)
    ap.add_argument("--out", default=OUT)
    ap.add_argument("--check-against", default=None,
                    help="committed BENCH_engine.json to gate speedups "
                         "against (fail on >20%% regression); also gates "
                         "telemetry overhead")
    ap.add_argument("--max-telemetry-overhead", type=float, default=0.05,
                    help="fail when an observed compiled run's steady-"
                         "state steps/s falls more than this fraction "
                         "below the unobserved run's")
    ap.add_argument("--participation", action="store_true",
                    help="also time fl with Participation(N, k=10) at "
                         f"N in {PART_CLIENTS} (compiled whole-run; "
                         "steps/s should stay ~flat in N)")
    ap.add_argument("--participation-only", action="store_true",
                    help="run ONLY the participation column and merge "
                         "its rows/keys into an existing --out JSON")
    ap.add_argument("--shard", action="store_true",
                    help="also time the compiled engine with shard=True "
                         "(hospital axis on the hosp device mesh; run "
                         "under XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N or on a multi-device host); "
                         "recorded as ':shard' speedup keys")
    args = ap.parse_args()

    epochs_po = args.epochs or (1 if args.smoke else 2)
    if args.participation_only:
        rows, part, scale = run_participation_grid(args.batch,
                                                   args.run_epochs,
                                                   epochs_po)
        out = {}
        if os.path.exists(args.out):
            with open(args.out) as f:
                out = json.load(f)
        out.setdefault("results", [])
        out["results"] = [r for r in out["results"]
                          if "part" not in r.get("mode", "")] + rows
        out["participation"] = part
        out.setdefault("speedup", {})[
            f"fl@{PART_CLIENTS[-1]}:part_scale"] = scale
        out_dir = os.path.dirname(args.out)
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2)
        print(f"merged participation column into {args.out}")
        return

    methods = (args.methods.split(",") if args.methods
               else (["fl"] if args.smoke else DEFAULT_METHODS))
    clients_grid = ([int(x) for x in args.clients.split(",")]
                    if args.clients else ([3] if args.smoke
                                          else DEFAULT_CLIENTS))
    epochs = args.epochs or (1 if args.smoke else 2)
    tpc = args.train_per_client or (16 if args.smoke else 128)

    # shard=True times the compiled engine with the hospital axis on the
    # hosp mesh; the stepwise baseline never shards, so the ':shard'
    # speedup key gates the SHARDED compiled path against the same oracle
    shard_grid = [False] + ([True] if args.shard else [])
    results, speedup, overhead = [], {}, {}
    for n in clients_grid:
        clients, adapter = build_setup(n, tpc, image_size=8)
        # telemetry overhead needs enough steps per run to amortize the
        # fixed metric-stack readback (a realistic run has hundreds of
        # steps; the smoke grid's ~10 would gate host-transfer latency,
        # not the taps' marginal cost)
        tel_tpc = max(tpc, 96)
        tel_clients, tel_adapter = ((clients, adapter) if tel_tpc == tpc
                                    else build_setup(n, tel_tpc,
                                                     image_size=8))
        for method in methods:
            # telemetry overhead: compiled whole-run with and without the
            # full tap spec — same program shape, extra scan outputs only
            plain = time_whole_run(method, "compiled", tel_clients,
                                   tel_adapter, args.batch,
                                   args.run_epochs, epochs)
            obs = time_whole_run(method, "compiled", tel_clients,
                                 tel_adapter, args.batch, args.run_epochs,
                                 epochs, observe=True)
            results.append(obs)     # the plain run3 row rides the grid below
            ov = plain["steps_per_sec"] / max(obs["steps_per_sec"],
                                              1e-9) - 1.0
            overhead[f"{method}@{n}"] = round(max(ov, 0.0), 4)
            print(f"{method:10s} n={n:3d} telemetry "
                  f"{obs['steps_per_sec']:9.1f} vs "
                  f"{plain['steps_per_sec']:9.1f} steps/s "
                  f"({max(ov, 0.0) * 100:5.2f}% overhead, "
                  f"{obs['dispatches_per_run']} dispatch/run)")
            for mode_fn, tag in (
                    (lambda m, e, sh: time_engine(m, e, clients, adapter,
                                                  args.batch, epochs,
                                                  shard=sh), ""),
                    (lambda m, e, sh: time_whole_run(m, e, clients, adapter,
                                                     args.batch,
                                                     args.run_epochs,
                                                     epochs, shard=sh),
                     f":run{args.run_epochs}")):
                base = mode_fn(method, "stepwise", False)
                results.append(base)
                print(f"{method:10s} n={n:3d} {'stepwise':15s} "
                      f"{base['mode']:6s} {base['steps_per_sec']:9.1f} "
                      f"steps/s ({base['epoch_seconds'] * 1e3:8.1f} ms)")
                for sh in shard_grid:
                    r = mode_fn(method, "compiled", sh)
                    results.append(r)
                    name = "compiled" + (":shard" if sh else "")
                    print(f"{method:10s} n={n:3d} {name:15s} "
                          f"{r['mode']:6s} {r['steps_per_sec']:9.1f} "
                          f"steps/s ({r['epoch_seconds'] * 1e3:8.1f} ms)")
                    sp = r["steps_per_sec"] / base["steps_per_sec"]
                    key = f"{method}@{n}{tag}" + (":shard" if sh else "")
                    speedup[key] = round(sp, 2)
                    print(f"{method:10s} n={n:3d} speedup {name:8s}"
                          f" {sp:7.2f}x")

        # raw-speed grid: fused cut-layer kernel and bf16 compute vs the
        # fp32-UNFUSED oracle, compiled whole-run only.  Non-split methods
        # have no cut layer, so their grid is precision-only; when the
        # method list has no split member one is added so the fused kernel
        # is always gated.  ':fused' / ':bf16' speedup keys feed the same
        # --check-against floor as the engine speedups.  Skipped under
        # --shard: virtual-device hosts split the thread pool and measure
        # scheduler noise, not the kernel — the unsharded smoke job owns
        # this gate.
        if args.shard:
            continue
        raw_methods = list(methods)
        if all(m in ("fl", "centralized") for m in raw_methods):
            raw_methods.append("sl_am")
        raw_reps = max(epochs, 5)
        for method in raw_methods:
            split = method not in ("fl", "centralized")
            variants = ([("fp32", False), ("fp32", True), ("bf16", True)]
                        if split else [("fp32", None), ("bf16", None)])
            rows = time_raw_grid(method, tel_clients, tel_adapter,
                                 args.batch, args.run_epochs, raw_reps,
                                 variants)
            base = rows[0]
            for r in rows:
                results.append(r)
                name = r["precision"] + {None: "", True: "+fused",
                                         False: "+unfused"}[r["fused"]]
                hbm = (r.get("memory") or {}).get("temp_size_in_bytes")
                print(f"{method:10s} n={n:3d} {name:15s} "
                      f"run{args.run_epochs:<3d} "
                      f"{r['steps_per_sec']:9.1f} steps/s"
                      + (f" (temp HBM {hbm / 1e3:.1f} kB)" if hbm else ""))
                if r is base:
                    continue
                tagv = "bf16" if r["precision"] == "bf16" else "fused"
                sp = r["steps_per_sec"] / base["steps_per_sec"]
                speedup[f"{method}@{n}:{tagv}"] = round(sp, 2)
                print(f"{method:10s} n={n:3d} speedup {tagv:8s}"
                      f" {sp:7.2f}x  (vs fp32"
                      + (":unfused)" if split else ")"))

    out = {"device": jax.devices()[0].device_kind,
           "n_devices": jax.device_count(),
           "batch_size": args.batch, "train_per_client": tpc,
           "epochs_timed": epochs, "run_epochs": args.run_epochs,
           "results": results, "speedup": speedup,
           "telemetry_overhead": overhead}
    if args.participation:
        rows, part, scale = run_participation_grid(args.batch,
                                                   args.run_epochs, epochs)
        out["results"] += rows
        out["participation"] = part
        speedup[f"fl@{PART_CLIENTS[-1]}:part_scale"] = scale
    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")

    if args.check_against:
        failures = check_against(args.check_against, speedup)
        if failures:
            print(f"FAIL: speedup regressed >20% vs committed baseline "
                  f"for {failures}")
            sys.exit(1)
        print("speedup gate OK (within 20% of committed baseline)")
        tel_failures = check_telemetry_overhead(overhead,
                                                args.max_telemetry_overhead)
        if tel_failures:
            print(f"FAIL: telemetry overhead above "
                  f"{args.max_telemetry_overhead * 100:.0f}% for "
                  f"{tel_failures}")
            sys.exit(1)
        print("telemetry overhead gate OK "
              f"(<={args.max_telemetry_overhead * 100:.0f}%)")


if __name__ == "__main__":
    main()
