"""Roofline analysis (deliverable g): reads the dry-run JSON records and
emits the per-(arch × shape) three-term table for EXPERIMENTS.md §Roofline.

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s          (197 TF bf16)
  memory term     = HLO_bytes_per_device / HBM_bw               (819 GB/s)
  collective term = collective_bytes_per_device / ICI link bw   (~50 GB/s)

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); the usefulness ratio
MODEL_FLOPS/HLO_FLOPs flags remat/redundancy waste (values > ~0.5 are good
for a remat-everything policy; tiny values indicate structural waste).
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import REGISTRY
from repro.launch.dryrun import HW
from repro.models.transformer import TransformerLM, layer_kinds


def param_counts(cfg):
    """(total_params, active_params) — analytic, no allocation."""
    import jax
    model = TransformerLM.build(cfg)
    shapes = jax.eval_shape(model.init_params, jax.random.key(0))
    import numpy as np
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    active = total
    if cfg.n_experts:
        # per MoE layer only top_k (+shared) experts are active
        per_expert = 3 * cfg.d_model * cfg.d_ff
        n_moe = sum(1 for k in layer_kinds(cfg) if k == "moe")
        inactive = n_moe * (cfg.n_experts - cfg.top_k) * per_expert
        active = total - inactive
    return total, active


def model_flops(arch_id: str, shape_name: str) -> float:
    """6·N_active·D for a train step (fwd+bwd); 2·N_active·D per decode/
    prefill token."""
    cfg = REGISTRY[arch_id].config
    sh = INPUT_SHAPES[shape_name]
    _, active = param_counts(cfg)
    if sh["kind"] == "train":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 6.0 * active * tokens
    if sh["kind"] == "prefill":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 2.0 * active * tokens
    return 2.0 * active * sh["global_batch"]          # decode: 1 token/seq


def load_records(results_dir="benchmarks/results", mesh="single"):
    recs = {}
    for f in glob.glob(os.path.join(results_dir, f"dryrun_*_{mesh}.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"])] = r
    return recs


def roofline_table(results_dir="benchmarks/results", mesh="single",
                   chips=256):
    rows = []
    recs = load_records(results_dir, mesh)
    for aid in REGISTRY:
        for shape in INPUT_SHAPES:
            r = recs.get((aid, shape))
            if r is None or r["status"] != "ok":
                rows.append({"arch": aid, "shape": shape,
                             "status": (r or {}).get("status", "missing"),
                             "notes": (r or {}).get("notes", "")})
                continue
            rf = r["roofline"]
            mf = model_flops(aid, shape)
            hlo_total = r["hlo_flops"] * chips   # cost_analysis is per device
            rows.append({
                "arch": aid, "shape": shape, "status": "ok",
                "t_compute_s": rf["t_compute"],
                "t_memory_s": rf["t_memory"],
                "t_collective_s": rf["t_collective"],
                "dominant": rf["dominant"],
                "model_flops": mf,
                "useful_ratio": mf / hlo_total if hlo_total else float("nan"),
                "collective_gb": rf["collective_bytes"] / 1e9,
            })
    return rows


def main():
    rows = roofline_table()
    print("arch,shape,dominant,t_compute_s,t_memory_s,t_collective_s,"
          "useful_ratio,collective_gb_per_dev")
    for r in rows:
        if r.get("status") != "ok":
            print(f"{r['arch']},{r['shape']},{r['status']},,,,,")
            continue
        print(f"{r['arch']},{r['shape']},{r['dominant']},"
              f"{r['t_compute_s']:.3e},{r['t_memory_s']:.3e},"
              f"{r['t_collective_s']:.3e},{r['useful_ratio']:.3f},"
              f"{r['collective_gb']:.3f}")


if __name__ == "__main__":
    main()
