"""Roofline analysis (deliverable g) — two sources, one three-term model:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / ICI link bw

``--mode dryrun`` (the original table) reads the transformer dry-run JSON
records and emits the per-(arch × shape) table for EXPERIMENTS.md
§Roofline.  MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE); the
usefulness ratio MODEL_FLOPS/HLO_FLOPs flags remat/redundancy waste
(values > ~0.5 are good for a remat-everything policy; tiny values
indicate structural waste).

``--mode strategies`` (the default) builds the five healthcare strategy
WHOLE-RUN programs (fl, sl_am, sflv2_ac, sflv3_ac, sflv1_ac — compiled
engine, int8 cut-layer transport on the split family), runs each once so
``obs.profile.hlo_cost`` can re-lower the exact donated program, and
writes the per-strategy compute/memory/collective cost table to
``benchmarks/results/BENCH_roofline.json``.

Hardware peaks come from ``launch.dryrun.HW_TABLE``; ``--hw`` selects the
row (default: whatever matches the current backend, so a CPU smoke run
labels its roofline as ``cpu_host`` instead of pretending TPU ceilings).
"""

from __future__ import annotations

import glob
import json
import os

from repro.launch.dryrun import HW_TABLE, default_hw, roofline_terms


def param_counts(cfg):
    """(total_params, active_params) — analytic, no allocation."""
    import jax
    from repro.models.transformer import TransformerLM, layer_kinds
    model = TransformerLM.build(cfg)
    shapes = jax.eval_shape(model.init_params, jax.random.key(0))
    import numpy as np
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    active = total
    if cfg.n_experts:
        # per MoE layer only top_k (+shared) experts are active
        per_expert = 3 * cfg.d_model * cfg.d_ff
        n_moe = sum(1 for k in layer_kinds(cfg) if k == "moe")
        inactive = n_moe * (cfg.n_experts - cfg.top_k) * per_expert
        active = total - inactive
    return total, active


def model_flops(arch_id: str, shape_name: str) -> float:
    """6·N_active·D for a train step (fwd+bwd); 2·N_active·D per decode/
    prefill token."""
    from repro.configs.base import INPUT_SHAPES
    from repro.configs.registry import REGISTRY
    cfg = REGISTRY[arch_id].config
    sh = INPUT_SHAPES[shape_name]
    _, active = param_counts(cfg)
    if sh["kind"] == "train":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 6.0 * active * tokens
    if sh["kind"] == "prefill":
        tokens = sh["global_batch"] * sh["seq_len"]
        return 2.0 * active * tokens
    return 2.0 * active * sh["global_batch"]          # decode: 1 token/seq


def load_records(results_dir="benchmarks/results", mesh="single"):
    recs = {}
    for f in glob.glob(os.path.join(results_dir, f"dryrun_*_{mesh}.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"])] = r
    return recs


def roofline_table(results_dir="benchmarks/results", mesh="single",
                   chips=256):
    from repro.configs.base import INPUT_SHAPES
    from repro.configs.registry import REGISTRY
    rows = []
    recs = load_records(results_dir, mesh)
    for aid in REGISTRY:
        for shape in INPUT_SHAPES:
            r = recs.get((aid, shape))
            if r is None or r["status"] != "ok":
                rows.append({"arch": aid, "shape": shape,
                             "status": (r or {}).get("status", "missing"),
                             "notes": (r or {}).get("notes", "")})
                continue
            rf = r["roofline"]
            mf = model_flops(aid, shape)
            hlo_total = r["hlo_flops"] * chips   # cost_analysis is per device
            rows.append({
                "arch": aid, "shape": shape, "status": "ok",
                "t_compute_s": rf["t_compute"],
                "t_memory_s": rf["t_memory"],
                "t_collective_s": rf["t_collective"],
                "dominant": rf["dominant"],
                "model_flops": mf,
                "useful_ratio": mf / hlo_total if hlo_total else float("nan"),
                "collective_gb": rf["collective_bytes"] / 1e9,
            })
    return rows


# ---------------------------------------------------------------------------
# per-strategy roofline — the five healthcare run programs
# ---------------------------------------------------------------------------

STRATEGY_METHODS = ["fl", "sl_am", "sflv2_ac", "sflv3_ac", "sflv1_ac"]
STRAT_OUT = os.path.join(os.path.dirname(__file__), "results",
                         "BENCH_roofline.json")


def _strategy_cost(method: str, n_clients: int, train_per_client: int,
                   batch_size: int, run_epochs: int, precision: str,
                   fused: bool):
    """Train one compiled whole-run program and return its HLO cost."""
    import jax
    import numpy as np
    from repro import optim as O
    from repro.core.partition import cnn_adapter
    from repro.core.strategies import make_strategy
    from repro.data.synthetic import make_cxr_clients
    from repro.models.cnn import DenseNetConfig, build_densenet
    from repro.obs.profile import hlo_cost
    from repro.wire import Transport

    clients = make_cxr_clients(seed=0, n_clients=n_clients,
                               train_per_client=train_per_client,
                               val_per_client=8, test_per_client=8,
                               image_size=8)
    cfg = DenseNetConfig(growth=2, blocks=(1, 1), stem_ch=4, cut_layer=1)
    adapter = cnn_adapter(build_densenet(cfg))
    transport = (Transport("int8", fuse=fused)
                 if method not in ("fl", "centralized") else None)
    strat = make_strategy(method, adapter, lambda: O.adam(1e-3), n_clients,
                          transport=transport, precision=precision)
    state = strat.setup(jax.random.key(0))
    state, logs = strat.run(state, [c.train for c in clients],
                            np.random.default_rng(0), batch_size,
                            run_epochs)
    steps = sum(l.steps for l in logs)
    return steps, hlo_cost(strat)


def strategy_roofline(methods=None, n_clients=3, train_per_client=16,
                      batch_size=4, run_epochs=2, hw=None,
                      precision="fp32", fused=True) -> dict:
    """Per-strategy compute/memory/collective cost table.

    Each row is the strategy's WHOLE-RUN compiled program (``run_epochs``
    epochs, every round's trip count folded in by
    ``launch.hlo_analysis``), divided by the ``hw`` peaks into the three
    roofline terms.  Memory figures are the compiler's buffer-assignment
    view (``memory_analysis``) — ``alias_size_in_bytes`` is what the
    donated carries save off peak.
    """
    hw_name = hw or default_hw()
    peaks = HW_TABLE[hw_name]
    rows = []
    for method in (methods or STRATEGY_METHODS):
        steps, cost = _strategy_cost(method, n_clients, train_per_client,
                                     batch_size, run_epochs, precision,
                                     fused)
        if cost is None:                       # degenerate run: no program
            rows.append({"strategy": method, "status": "no_compiled_run"})
            continue
        rf = roofline_terms({"hlo_flops": cost["flops"],
                             "hlo_bytes": cost["hbm_bytes"],
                             "collectives": cost["collective_bytes"]},
                            mesh_chips=1, hw=peaks)
        row = {"strategy": method, "status": "ok", "steps": steps,
               "flops": cost["flops"], "hbm_bytes": cost["hbm_bytes"],
               "collective_bytes": cost["collective_total"],
               "t_compute_s": rf["t_compute"], "t_memory_s": rf["t_memory"],
               "t_collective_s": rf["t_collective"],
               "dominant": rf["dominant"],
               "compile_seconds": cost["compile_seconds"]}
        if "memory" in cost:
            row["memory"] = cost["memory"]
        rows.append(row)
    return {"hw": hw_name, "peaks": peaks, "n_clients": n_clients,
            "train_per_client": train_per_client, "batch_size": batch_size,
            "run_epochs": run_epochs, "precision": precision,
            "fused": fused, "rows": rows}


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="strategies",
                    choices=["strategies", "dryrun"])
    ap.add_argument("--hw", default=None, choices=list(HW_TABLE),
                    help="hardware peaks row (default: match the backend)")
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--train-per-client", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--run-epochs", type=int, default=2)
    ap.add_argument("--precision", default="fp32",
                    choices=["fp32", "bf16"])
    ap.add_argument("--unfused", action="store_true",
                    help="force the unfused cut-layer reference path")
    ap.add_argument("--out", default=STRAT_OUT)
    args = ap.parse_args()

    if args.mode == "dryrun":
        rows = roofline_table()
        print("arch,shape,dominant,t_compute_s,t_memory_s,t_collective_s,"
              "useful_ratio,collective_gb_per_dev")
        for r in rows:
            if r.get("status") != "ok":
                print(f"{r['arch']},{r['shape']},{r['status']},,,,,")
                continue
            print(f"{r['arch']},{r['shape']},{r['dominant']},"
                  f"{r['t_compute_s']:.3e},{r['t_memory_s']:.3e},"
                  f"{r['t_collective_s']:.3e},{r['useful_ratio']:.3f},"
                  f"{r['collective_gb']:.3f}")
        return

    table = strategy_roofline(
        n_clients=args.clients, train_per_client=args.train_per_client,
        batch_size=args.batch, run_epochs=args.run_epochs, hw=args.hw,
        precision=args.precision, fused=not args.unfused)
    out_dir = os.path.dirname(args.out)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(table, f, indent=1)
    print(f"hw={table['hw']}  "
          "strategy,dominant,t_compute_s,t_memory_s,t_collective_s,"
          "compile_s")
    for r in table["rows"]:
        if r.get("status") != "ok":
            print(f"{r['strategy']},{r['status']},,,,")
            continue
        print(f"{r['strategy']},{r['dominant']},{r['t_compute_s']:.3e},"
              f"{r['t_memory_s']:.3e},{r['t_collective_s']:.3e},"
              f"{r['compile_seconds']:.2f}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
