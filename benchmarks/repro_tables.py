"""Faithful reproduction of the paper's comparison (Tables 2/3/4/5/6) on the
synthetic 5-hospital non-IID CXR task (see DESIGN.md §1 data gate).

Runs the full 10-method grid of Table 2 for both model families
(DenseNet-mini, U-Net-mini), with best-validation-loss model selection as in
§3.2, and records per-epoch wall time (Table 3), analytic communication
(Table 4) and XLA-counted FLOPs (Tables 5/6).

  PYTHONPATH=src python -m benchmarks.repro_tables [--quick] [--arch X]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro import optim as O
from repro.configs.paper_models import DENSENET_MINI, UNET_MINI
from repro.core.comm import comm_per_epoch
from repro.core.flops import flops_per_epoch, segment_fwd_flops

_SEG_FWD_CACHE: dict = {}
from repro.core.partition import cnn_adapter
from repro.core.strategies import make_strategy
from repro.data.synthetic import make_cxr_clients
from repro.models.cnn import build_densenet, build_unet

# the paper's Table-2 method grid (label, strategy key, nls?)
ROWS = [
    ("Centralized",  "centralized", False),
    ("FL",           "fl",          False),
    ("SL_LS_AC",     "sl_ac",       False),
    ("SL_LS_AM",     "sl_am",       False),
    ("SL_NLS_AC",    "sl_ac",       True),
    ("SL_NLS_AM",    "sl_am",       True),
    ("SFLv2_LS_AC",  "sflv2_ac",    False),
    ("SFLv2_NLS_AC", "sflv2_ac",    True),
    ("SFLv3_LS_AC",  "sflv3_ac",    False),
    ("SFLv3_NLS_AC", "sflv3_ac",    True),
    ("SFLv1_LS_AC",  "sflv1_ac",    False),   # bonus (paper excluded SFLv1)
]


def build_model(arch: str, nls: bool):
    if arch == "densenet-mini":
        return cnn_adapter(build_densenet(DENSENET_MINI, nls=nls))
    if arch == "unet-mini":
        return cnn_adapter(build_unet(UNET_MINI, nls=nls))
    raise KeyError(arch)


_STRAT_CACHE: dict = {}


def run_method(label, method, nls, arch, clients, epochs, batch_size, lr,
               seed=0):
    # reuse the strategy (and its jitted steps) across seeds — compile once
    skey = (label, arch, batch_size)
    if skey not in _STRAT_CACHE:
        adapter = build_model(arch, nls)
        _STRAT_CACHE[skey] = make_strategy(method, adapter,
                                           lambda: O.adam(lr), len(clients))
    strat = _STRAT_CACHE[skey]
    adapter = strat.adapter
    state = strat.setup(jax.random.key(seed))
    rng = np.random.default_rng(seed)
    data = [c.train for c in clients]

    best = {"val_loss": float("inf"), "state": None}
    epoch_times = []
    for ep in range(epochs):
        t0 = time.time()
        state, log = strat.run_epoch(state, data, rng, batch_size)
        epoch_times.append(time.time() - t0)
        vl = strat.val_loss(state, clients, batch_size)
        if vl < best["val_loss"]:
            best = {"val_loss": vl,
                    "state": jax.tree.map(lambda x: x, state)}
        print(f"    ep{ep} loss={log.mean_loss:.3f} val={vl:.3f} "
              f"t={epoch_times[-1]:.1f}s", flush=True)

    metrics = strat.evaluate(best["state"], clients, "test", batch_size)

    n_train = [len(d["label"]) for d in data]
    n_val = [len(c.val["label"]) for c in clients]
    eb = {k: v[:batch_size] for k, v in data[0].items()}
    comm = comm_per_epoch(method if method != "centralized" else
                          "centralized", adapter, eb, n_train, n_val,
                          batch_size)
    key = (arch, nls, batch_size)
    if key not in _SEG_FWD_CACHE:
        _SEG_FWD_CACHE[key] = segment_fwd_flops(adapter, eb)
    fl = flops_per_epoch(method, adapter, eb, n_train, batch_size,
                         seg_fwd=_SEG_FWD_CACHE[key])
    return {
        "label": label, "method": method, "nls": nls, "arch": arch,
        **{k: round(float(v), 4) for k, v in metrics.items()},
        "best_val_loss": round(float(best["val_loss"]), 4),
        # first epoch includes jit compile; steady state = median of rest
        "epoch_time_s": round(float(np.median(epoch_times[1:])
                                    if len(epoch_times) > 1
                                    else epoch_times[0]), 2),
        "comm_gb": round(comm.gb, 6),
        "comm_breakdown": {k: int(v) for k, v in comm.breakdown.items()},
        "server_tflops": round(fl.server_tflops, 6),
        "avg_client_tflops": round(fl.avg_client_tflops, 6),
        "averaging_mflops": round(fl.averaging_mflops, 6),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seeds", type=int, default=3)
    ap.add_argument("--arch", default=None,
                    choices=[None, "densenet-mini", "unet-mini"])
    ap.add_argument("--out", default="benchmarks/results")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    size = 32
    # unequal hospital volumes, like the paper's 3772/1150/1816/880/1090
    sizes = [40, 16, 24, 16, 24] if args.quick else [160, 80, 120, 64, 96]
    clients = make_cxr_clients(seed=0, train_per_client=sizes,
                               val_per_client=60, test_per_client=60,
                               image_size=size)
    plans = {
        "densenet-mini": {"epochs": 2 if args.quick else 10,
                          "batch": 8, "lr": 3e-4},
        "unet-mini": {"epochs": 2 if args.quick else 5,
                      "batch": 8, "lr": 3e-4},
    }
    archs = [args.arch] if args.arch else list(plans)
    for arch in archs:
        plan = plans[arch]
        out_path = os.path.join(args.out, f"repro_{arch}.json")
        results = []
        if os.path.exists(out_path):            # resume partial runs
            results = json.load(open(out_path))
        done = {(r["label"], r.get("seed", 0)) for r in results}
        n_seeds = 1 if args.quick else args.seeds
        for label, method, nls in ROWS:
            for seed in range(n_seeds):
                if (label, seed) in done:
                    continue
                print(f"== {arch} {label} seed{seed}", flush=True)
                t0 = time.time()
                rec = run_method(label, method, nls, arch, clients,
                                 plan["epochs"], plan["batch"], plan["lr"],
                                 seed=seed)
                rec["seed"] = seed
                rec["wall_s"] = round(time.time() - t0, 1)
                results.append(rec)
                print(f"   -> auroc={rec['auroc']} auprc={rec['auprc']} "
                      f"f1={rec['f1']} kappa={rec['kappa']} "
                      f"comm={rec['comm_gb']}GB", flush=True)
                with open(out_path, "w") as f:
                    json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
