"""Privacy sweep — noise multiplier x strategy -> (eps, AUROC, leakage,
bytes): the (eps, delta)-vs-utility frontier the paper's comparison was
missing.

For every method of the paper's grid (FL / SL / SFLv2 / SFLv3) and every
DP-SGD noise multiplier, train on the synthetic 5-hospital CXR task and
report:

  * accountant epsilon at delta=1e-5 — PER HOSPITAL (unequal data volumes
    give unequal guarantees), plus the worst case;
  * pooled test AUROC;
  * cut-layer leakage: distance correlation of the smashed activations
    against raw inputs, measured on exactly what crosses the wire (through
    the identity Transport; FL has no cut layer so its column reports the
    HYPOTHETICAL leakage of the shared model's front segment — what an SL
    deployment of the same weights would expose);
  * on-wire bytes: metered cut-layer traffic for the split family, model
    up/down (+ secure-aggregation handshake) for FL.

Writes ``benchmarks/results/privacy_sweep.json`` + ``.md``.

  PYTHONPATH=src python -m benchmarks.privacy_sweep [--quick]
      [--methods fl,sl_ac,...] [--noise 0,0.5,1,2] [--epochs N]
"""

from __future__ import annotations

import argparse
import json
import math
import os

import jax
import numpy as np

from repro import optim as O
from repro.core.comm import comm_per_epoch
from repro.core.partition import cnn_adapter
from repro.core.strategies import make_strategy
from repro.data.synthetic import make_cxr_clients
from repro.models.cnn import DenseNetConfig, build_densenet
from repro.privacy import PrivacyConfig, measure_leakage
from repro.wire import Transport

DEFAULT_METHODS = ["fl", "sl_ac", "sflv2_ac", "sflv3_ac"]
DEFAULT_NOISE = [0.0, 0.5, 1.0, 2.0]
CLIP_NORM = 1.0
DELTA = 1e-5


def build_setup(quick: bool):
    n_tr = [24, 48, 24, 48, 24] if quick else [96, 192, 48, 96, 48]
    clients = make_cxr_clients(seed=0, train_per_client=n_tr,
                               val_per_client=16 if quick else 32,
                               test_per_client=24 if quick else 48,
                               image_size=16 if quick else 32)
    cfg = (DenseNetConfig(growth=4, blocks=(1, 1), stem_ch=8, cut_layer=1)
           if quick else
           DenseNetConfig(growth=8, blocks=(2, 2), stem_ch=8, cut_layer=1))
    adapter = cnn_adapter(build_densenet(cfg))
    return adapter, clients


def fl_bytes(adapter, clients, batch_size, epochs, strat) -> float:
    """FL on-wire bytes: model down/up per round (+ secagg handshake)."""
    n_tr = [len(c.train["label"]) for c in clients]
    n_va = [len(c.val["label"]) for c in clients]
    example = {k: v[:batch_size] for k, v in clients[0].train.items()}
    per_round = comm_per_epoch("fl", adapter, example, n_tr, n_va,
                               batch_size).bytes_per_epoch
    extra = (strat.secagg.handshake_bytes() * strat.secagg.rounds
             if hasattr(strat, "secagg") else 0)
    return per_round * epochs + extra


def run_cell(method, sigma, adapter, clients, epochs, batch_size,
             secagg_fl=True, seed=0) -> dict:
    privacy = PrivacyConfig(noise_multiplier=sigma, clip_norm=CLIP_NORM,
                            delta=DELTA, seed=seed,
                            secagg=secagg_fl and method == "fl")
    transport = Transport("identity") if method != "fl" else None
    strat = make_strategy(method, adapter, lambda: O.adam(1e-3),
                          len(clients), transport=transport,
                          privacy=privacy)
    state = strat.setup(jax.random.key(seed))
    rng = np.random.default_rng(seed)
    log = None
    for _ in range(epochs):
        state, log = strat.run_epoch(state, [c.train for c in clients],
                                     rng, batch_size)
    metrics = strat.evaluate(state, clients, "test", batch_size=32)

    report = strat.privacy_report()
    eps = [r["epsilon"] for r in report]

    # leakage of what actually ships, hospital 0's front segment
    params = strat.params_for_eval(state, 0)
    probe_batch = {k: v[:64] for k, v in clients[0].test.items()}
    leak = measure_leakage(adapter, params, probe_batch,
                           transport=transport, seed=seed)

    if method == "fl":
        wire = fl_bytes(adapter, clients, batch_size, epochs, strat)
    else:
        wire = transport.bytes_on_wire

    return {
        "method": method, "noise_multiplier": sigma,
        "clip_norm": CLIP_NORM, "delta": DELTA,
        "epsilon_per_hospital": eps,
        "epsilon_max": max(eps) if eps else math.inf,
        "auroc": metrics["auroc"], "auprc": metrics["auprc"],
        "sensitivity": metrics["sensitivity"],
        "specificity": metrics["specificity"], "ece": metrics["ece"],
        "dcor_input": leak["dcor_input"],
        "probe_r2": leak["probe"]["r2"],
        "label_probe_auc": leak.get("label_probe_auc", float("nan")),
        "bytes_on_wire": float(wire),
        "mean_train_loss": log.mean_loss,
    }


def _fmt_eps(e: float) -> str:
    return "inf" if math.isinf(e) else f"{e:.2f}"


def markdown_report(rows) -> str:
    out = ["# Privacy sweep — DP-SGD noise multiplier x strategy", "",
           f"Per-example clip C={CLIP_NORM}, delta={DELTA}; epsilon is the "
           "WORST hospital (per-hospital list in the JSON).  FL leakage "
           "columns are the hypothetical exposure of the shared front "
           "segment.", "",
           "| method | sigma | eps (max) | AUROC | dCor(input) | probe R2 "
           "| label AUC | wire MB |", "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['method']} | {r['noise_multiplier']:g} | "
            f"{_fmt_eps(r['epsilon_max'])} | {r['auroc']:.3f} | "
            f"{r['dcor_input']:.3f} | {r['probe_r2']:.3f} | "
            f"{r['label_probe_auc']:.3f} | "
            f"{r['bytes_on_wire'] / 1e6:.2f} |")
    out.append("")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--methods", default=",".join(DEFAULT_METHODS))
    ap.add_argument("--noise", default=",".join(str(s) for s in
                                                DEFAULT_NOISE))
    ap.add_argument("--epochs", type=int, default=None)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="benchmarks/results")
    args = ap.parse_args(argv)

    adapter, clients = build_setup(args.quick)
    epochs = args.epochs if args.epochs is not None else (1 if args.quick
                                                          else 2)
    rows = []
    for method in args.methods.split(","):
        for sigma in [float(s) for s in args.noise.split(",")]:
            r = run_cell(method, sigma, adapter, clients, epochs,
                         args.batch_size, seed=args.seed)
            rows.append(r)
            print(f"  {method:9s} sigma={sigma:4g} "
                  f"eps={_fmt_eps(r['epsilon_max']):>7s} "
                  f"auroc={r['auroc']:.3f} dcor={r['dcor_input']:.3f} "
                  f"wire={r['bytes_on_wire'] / 1e6:8.2f} MB")

    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, "privacy_sweep.json"), "w") as f:
        json.dump({"clip_norm": CLIP_NORM, "delta": DELTA,
                   "epochs": epochs, "sweep": rows}, f, indent=1,
                  default=float)
    with open(os.path.join(args.out, "privacy_sweep.md"), "w") as f:
        f.write(markdown_report(rows))
    print(f"\nwrote {args.out}/privacy_sweep.json and privacy_sweep.md")


if __name__ == "__main__":
    main()
