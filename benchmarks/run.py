"""Benchmark harness — one section per paper table + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV rows:
  * kernel microbenchmarks: real jit wall-time per call (CPU interpret for
    Pallas; the number that matters on TPU comes from the roofline terms)
  * Table 2/3/4/5/6 analogues: derived from benchmarks/results/repro_*.json
    (produced by ``python -m benchmarks.repro_tables``)
  * roofline: dominant-term seconds per (arch, shape) from the dry-run

  PYTHONPATH=src python -m benchmarks.run
"""

from __future__ import annotations

import json
import os
import time


def _timeit(fn, *args, warmup=1, iters=5):
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def bench_kernels(emit):
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import flash_attention_ref
    from repro.kernels.ssd_scan.ops import ssd
    from repro.kernels.act_compress.ops import quantize

    ks = jax.random.split(jax.random.key(0), 4)
    q = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    us = _timeit(flash_attention, q, k, v)
    ref = jax.jit(lambda a, b, c: flash_attention_ref(
        a.transpose(0, 2, 1, 3), b.transpose(0, 2, 1, 3),
        c.transpose(0, 2, 1, 3)))
    us_ref = _timeit(ref, q, k, v)
    emit("kernel/flash_attention_b1s256", us, f"ref_us={us_ref:.0f}")

    x = jax.random.normal(ks[0], (1, 256, 4, 32), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 256, 4)))
    A = jnp.exp(jax.random.normal(ks[2], (4,)) * 0.3)
    B = jax.random.normal(ks[3], (1, 256, 1, 32)) * 0.5
    C = jax.random.normal(ks[0], (1, 256, 1, 32)) * 0.5
    us = _timeit(lambda *a: ssd(*a, 64), x, dt, A, B, C)
    emit("kernel/ssd_scan_l256", us, "chunk=64")

    xa = jax.random.normal(ks[1], (512, 1024), jnp.bfloat16)
    us = _timeit(quantize, xa)
    emit("kernel/act_compress_512x1024", us,
         f"ratio={xa.nbytes / (512 * 1024 + 512 * 4):.2f}x")


def bench_tables(emit, results="benchmarks/results"):
    for arch in ("densenet-mini", "unet-mini"):
        path = os.path.join(results, f"repro_{arch}.json")
        if not os.path.exists(path):
            emit(f"table2/{arch}", 0, "MISSING (run benchmarks.repro_tables)")
            continue
        rows = json.load(open(path))
        for r in rows:
            emit(f"table2/{arch}/{r['label']}", r["wall_s"] * 1e6,
                 f"auroc={r['auroc']};auprc={r['auprc']};f1={r['f1']};"
                 f"kappa={r['kappa']}")
            emit(f"table3/{arch}/{r['label']}",
                 r["epoch_time_s"] * 1e6, "epoch_time")
            emit(f"table4/{arch}/{r['label']}", 0,
                 f"comm_gb={r['comm_gb']}")
            emit(f"table56/{arch}/{r['label']}", 0,
                 f"server_tf={r['server_tflops']};"
                 f"client_tf={r['avg_client_tflops']};"
                 f"avg_mf={r['averaging_mflops']}")


def bench_roofline(emit, results="benchmarks/results"):
    try:
        from benchmarks.roofline import roofline_table
    except Exception as e:
        emit("roofline", 0, f"ERROR {e}")
        return
    for r in roofline_table(results):
        if r.get("status") != "ok":
            continue
        dom_t = {"compute": r["t_compute_s"], "memory": r["t_memory_s"],
                 "collective": r["t_collective_s"]}[r["dominant"]]
        emit(f"roofline/{r['arch']}/{r['shape']}", dom_t * 1e6,
             f"dominant={r['dominant']};useful={r['useful_ratio']:.3f}")


def main() -> None:
    rows = []

    def emit(name, us, derived=""):
        rows.append((name, us, derived))
        print(f"{name},{us:.1f},{derived}", flush=True)

    print("name,us_per_call,derived")
    bench_kernels(emit)
    bench_tables(emit)
    bench_roofline(emit)
    print(f"# {len(rows)} rows", flush=True)


if __name__ == "__main__":
    main()
