"""Sweep codecs x network scenarios x methods through the wire simulator.

For every method of the paper's comparison, replay one epoch's cut-layer
transfer DAG through each (codec, scenario) pair and report bytes-on-wire,
simulated wall-clock, straggler sensitivity and per-client idle fractions.
The identity codec's byte total is cross-checked against the analytic
profile of ``repro.core.comm`` (paper Table 4) — the run fails loudly if
they disagree by more than 1%.  A second gate feeds a ``Transport`` the
exact per-epoch accounting the (default) compiled engine emits and checks
``timeline_from_accounting`` replays it to the same wall-clock and
per-tag bytes as ``simulate`` — so the sweep's numbers are valid
whichever engine trained.

Writes ``benchmarks/results/wire_sweep.json`` + ``.md``.

  PYTHONPATH=src python -m benchmarks.wire_sweep [--quick]
      [--methods sl_ac,...] [--codecs identity,...] [--scenarios lan,...]
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.core.comm import client_batch_counts, comm_per_epoch
from repro.core.partition import cnn_adapter
from repro.models.cnn import DenseNetConfig, build_densenet
from repro.wire import (Transport, make_codec, make_network, simulate,
                        straggler_sensitivity, timeline_from_accounting)

DEFAULT_METHODS = ["fl", "sl_ac", "sl_am", "sflv2_ac", "sflv3_ac"]
DEFAULT_CODECS = ["identity", "bf16", "int8", "topk:0.1"]
DEFAULT_SCENARIOS = ["lan", "hospital_wan", "cellular"]

# the paper's 5 hospitals with very different data volumes (3772 vs 880
# samples in the TB task), scaled down so the sweep stays CPU-fast
N_TRAIN = [472, 236, 110, 472, 236]
N_VAL = [118, 59, 28, 118, 59]
BATCH = 32


def build_setup(quick: bool):
    cfg = (DenseNetConfig(growth=8, blocks=(2, 4), stem_ch=8, cut_layer=1)
           if quick else
           DenseNetConfig(growth=12, blocks=(4, 8, 6), stem_ch=16,
                          cut_layer=2))
    size = 16 if quick else 32
    adapter = cnn_adapter(build_densenet(cfg))
    example = {"image": np.zeros((BATCH, size, size, 1), np.float32),
               "label": np.zeros((BATCH,), np.float32)}
    n_tr = [n // 4 for n in N_TRAIN] if quick else N_TRAIN
    n_va = [max(n // 4, BATCH) for n in N_VAL] if quick else N_VAL
    return adapter, example, n_tr, n_va


def check_identity_matches_analytic(adapter, example, n_tr, n_va) -> list:
    """Acceptance gate: identity-codec sim bytes == comm.py within 1%."""
    rows = []
    for method in DEFAULT_METHODS:
        analytic = comm_per_epoch(method, adapter, example, n_tr, n_va,
                                  BATCH).bytes_per_epoch
        sim = simulate(method, adapter, example, n_tr, n_va, BATCH,
                       "identity", "lan", keep_events=False).bytes_on_wire
        rel = abs(sim - analytic) / max(analytic, 1.0)
        rows.append({"method": method, "analytic": analytic, "sim": sim,
                     "rel_err": rel})
        if rel > 0.01:
            raise AssertionError(
                f"{method}: simulated bytes {sim:.0f} vs analytic "
                f"{analytic:.0f} differ by {rel:.2%} (> 1%)")
    return rows


def check_accounting_bridge(adapter, example, n_tr, n_va) -> list:
    """Acceptance gate: a Transport fed the compiled engine's analytic
    per-epoch accounting must replay (``timeline_from_accounting``) to the
    same wall-clock and per-tag bytes as the from-scratch ``simulate``."""
    rows = []
    tr_counts, _ = client_batch_counts(n_tr, n_va, BATCH)
    for method in ("sl_ac", "sl_am", "sflv2_ac", "sflv3_ac"):
        kind, _, schedule = method.partition("_")
        tp = Transport("identity")
        # exactly what the compiled engine's _account_compiled emits
        tp.record_epoch(adapter, example, kind, schedule, tr_counts)
        for nb in tr_counts:
            tp.account(adapter, example, count=nb)
        sim = simulate(method, adapter, example, n_tr, n_va, BATCH,
                       "identity", "lan", seed=0, keep_events=False)
        acc = timeline_from_accounting(tp, n_val=n_va, batch_size=BATCH,
                                       network="lan", seed=0,
                                       keep_events=False)
        if (acc.wall_clock_s != sim.wall_clock_s
                or acc.breakdown != sim.breakdown):
            raise AssertionError(
                f"{method}: accounting-fed timeline diverges from "
                f"simulate ({acc.wall_clock_s} vs {sim.wall_clock_s} s)")
        rows.append({"method": method, "wall_clock_s": acc.wall_clock_s,
                     "bytes_on_wire": acc.bytes_on_wire})
    return rows


def sweep(adapter, example, n_tr, n_va, methods, codecs, scenarios,
          seed=0) -> list:
    rows = []
    for scenario in scenarios:
        net = make_network(scenario)
        for codec_name in codecs:
            codec = make_codec(codec_name)
            for method in methods:
                if method == "fl" and codec_name != "identity":
                    continue           # FL has no cut layer to compress
                r = simulate(method, adapter, example, n_tr, n_va, BATCH,
                             codec, net, seed=seed, keep_events=False)
                sens = straggler_sensitivity(
                    method, adapter, example, n_tr, n_va, BATCH, codec,
                    net, seed=seed) if net.straggler_frac > 0 else 1.0
                idle = np.mean([pc["idle_frac"]
                                for pc in r.per_client.values()])
                rows.append({
                    "scenario": scenario, "codec": codec.name,
                    "method": method,
                    "bytes_on_wire": r.bytes_on_wire,
                    "bytes_raw": r.bytes_raw,
                    "compression_ratio": r.compression_ratio,
                    "wall_clock_s": r.wall_clock_s,
                    "straggler_sensitivity": sens,
                    "mean_client_idle_frac": float(idle),
                })
                print(f"  {scenario:12s} {codec.name:9s} {method:9s} "
                      f"{r.bytes_on_wire / 1e6:9.2f} MB "
                      f"{r.wall_clock_s:9.2f} s  sens={sens:5.2f}")
    return rows


def markdown_report(check_rows, rows) -> str:
    out = ["# Wire sweep — codecs x network scenarios x methods", ""]
    out.append("## Identity codec vs analytic comm profile (Table 4 gate)")
    out.append("")
    out.append("| method | analytic MB | simulated MB | rel err |")
    out.append("|---|---|---|---|")
    for r in check_rows:
        out.append(f"| {r['method']} | {r['analytic'] / 1e6:.3f} | "
                   f"{r['sim'] / 1e6:.3f} | {r['rel_err']:.2e} |")
    out.append("")
    out.append("## Sweep (one epoch, 5 hospitals)")
    out.append("")
    out.append("| scenario | codec | method | wire MB | ratio | wall s | "
               "straggler x | idle |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in rows:
        out.append(
            f"| {r['scenario']} | {r['codec']} | {r['method']} | "
            f"{r['bytes_on_wire'] / 1e6:.2f} | "
            f"{r['compression_ratio']:.2f} | {r['wall_clock_s']:.2f} | "
            f"{r['straggler_sensitivity']:.2f} | "
            f"{r['mean_client_idle_frac']:.2f} |")
    out.append("")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--methods", default=",".join(DEFAULT_METHODS))
    ap.add_argument("--codecs", default=",".join(DEFAULT_CODECS))
    ap.add_argument("--scenarios", default=",".join(DEFAULT_SCENARIOS))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="benchmarks/results")
    args = ap.parse_args(argv)

    adapter, example, n_tr, n_va = build_setup(args.quick)
    print("cross-checking identity codec vs repro.core.comm ...")
    check_rows = check_identity_matches_analytic(adapter, example, n_tr,
                                                 n_va)
    for r in check_rows:
        print(f"  {r['method']:9s} rel_err={r['rel_err']:.2e}  OK")

    print("cross-checking analytic-accounting timelines vs simulate ...")
    bridge_rows = check_accounting_bridge(adapter, example, n_tr, n_va)
    for r in bridge_rows:
        print(f"  {r['method']:9s} wall={r['wall_clock_s']:.2f}s  OK")

    print("sweeping ...")
    rows = sweep(adapter, example, n_tr, n_va, args.methods.split(","),
                 args.codecs.split(","), args.scenarios.split(","),
                 args.seed)

    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(args.out, "wire_sweep.json"), "w") as f:
        json.dump({"check": check_rows, "bridge_check": bridge_rows,
                   "sweep": rows}, f, indent=1)
    md = markdown_report(check_rows, rows)
    with open(os.path.join(args.out, "wire_sweep.md"), "w") as f:
        f.write(md)
    print(f"\nwrote {args.out}/wire_sweep.json and wire_sweep.md")


if __name__ == "__main__":
    main()
