"""Generates the EXPERIMENTS.md tables from benchmarks/results/*.json.

  PYTHONPATH=src python -m benchmarks.report
      [--section repro|dryrun|roofline|serving]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from collections import defaultdict

import numpy as np


def repro_section(results="benchmarks/results"):
    out = []
    claims = {}
    for arch in ("densenet-mini", "unet-mini"):
        path = os.path.join(results, f"repro_{arch}.json")
        if not os.path.exists(path):
            continue
        rows = json.load(open(path))
        by_label = defaultdict(list)
        for r in rows:
            by_label[r["label"]].append(r)
        out.append(f"\n### {arch} (mean ± std over "
                   f"{max(len(v) for v in by_label.values())} seeds)\n")
        out.append("| method | AUROC | AUPRC | F1 | kappa | epoch s | "
                   "comm GB | server TF | client TF | avg MF |")
        out.append("|---|---|---|---|---|---|---|---|---|---|")
        agg = {}
        for label, rs in by_label.items():
            m = {k: (np.mean([r[k] for r in rs]),
                     np.std([r[k] for r in rs]))
                 for k in ("auroc", "auprc", "f1", "kappa")}
            agg[label] = {k: v[0] for k, v in m.items()}
            r0 = rs[0]
            out.append(
                f"| {label} | " +
                " | ".join(f"{m[k][0]:.3f}±{m[k][1]:.3f}"
                           for k in ("auroc", "auprc", "f1", "kappa")) +
                f" | {np.mean([r['epoch_time_s'] for r in rs]):.2f}"
                f" | {r0['comm_gb']:.4f} | {r0['server_tflops']:.4f}"
                f" | {r0['avg_client_tflops']:.4f}"
                f" | {r0['averaging_mflops']:.3f} |")
        # claim checks (paper §4)
        c = {}
        if "Centralized" in agg:
            cen = agg["Centralized"]
            dist = [v for k, v in agg.items() if k != "Centralized"]
            c["C1 centralized best AUPRC"] = all(
                cen["auprc"] >= d["auprc"] - 1e-9 for d in dist)
            if "SFLv3_LS_AC" in agg:
                c["C2 SFLv3>SL (LS AC, AUROC)"] = (
                    agg["SFLv3_LS_AC"]["auroc"] > agg["SL_LS_AC"]["auroc"])
                c["C2 SFLv3>SFLv2 (LS AC, AUROC)"] = (
                    agg["SFLv3_LS_AC"]["auroc"] > agg["SFLv2_LS_AC"]["auroc"])
            if "SL_LS_AM" in agg:
                c["C3 AM>AC (LS, AUROC)"] = (
                    agg["SL_LS_AM"]["auroc"] > agg["SL_LS_AC"]["auroc"])
            if "SL_NLS_AM" in agg:
                c["C3 AM>AC (NLS, AUROC)"] = (
                    agg["SL_NLS_AM"]["auroc"] > agg["SL_NLS_AC"]["auroc"])
        rows0 = {r["label"]: r for r in rows}
        if "FL" in rows0 and "SL_LS_AC" in rows0:
            c["C4 FL comm << SL comm"] = (
                rows0["FL"]["comm_gb"] < 0.5 * rows0["SL_LS_AC"]["comm_gb"])
            c["C4 NLS comm > LS comm"] = (
                rows0["SL_NLS_AC"]["comm_gb"] > rows0["SL_LS_AC"]["comm_gb"])
            c["C5 FL client TF >> SL client TF"] = (
                rows0["FL"]["avg_client_tflops"] >
                2 * rows0["SL_LS_AC"]["avg_client_tflops"])
            c["C5 SL server >> SL client"] = (
                rows0["SL_LS_AC"]["server_tflops"] >
                2 * rows0["SL_LS_AC"]["avg_client_tflops"])
        claims[arch] = c
        out.append("\nClaim checks:")
        for k, v in c.items():
            out.append(f"* {'✅' if v else '❌'} {k}")
    return "\n".join(out), claims


def dryrun_section(results="benchmarks/results", mesh=None):
    out = ["| arch | shape | mesh | status | lower s | compile s | "
           "temp GB/dev | args GB/dev | collective GB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    files = sorted(glob.glob(os.path.join(results, "dryrun_*.json")))
    for f in files:
        if f.count("_") > 4 and not f.endswith(("single.json", "multi.json")):
            continue      # variant runs listed in §Perf instead
        r = json.load(open(f))
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"skipped | | | | | |")
            continue
        coll = sum(v for k, v in r.get("collectives", {}).items()
                   if k != "counts")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} | "
            f"{r.get('lower_s', 0):.1f} | {r.get('compile_s', 0):.1f} | "
            f"{r.get('temp_size_in_bytes', 0) / 1e9:.2f} | "
            f"{r.get('argument_size_in_bytes', 0) / 1e9:.2f} | "
            f"{coll / 1e9:.2f} |")
    return "\n".join(out)


def roofline_section(results="benchmarks/results"):
    from benchmarks.roofline import roofline_table
    rows = roofline_table(results)
    out = ["| arch | shape | dominant | compute s | memory s | "
           "collective s | useful 6ND/HLO | what would move it |",
           "|---|---|---|---|---|---|---|---|"]
    hints = {
        ("compute",): "more chips / lower precision matmuls",
        ("memory",): "fewer remat passes, fused layers, bf16 opt states",
        ("collective",): "shard/reshard less at the cut layer; overlap "
                         "collectives with compute; int8 link",
    }
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']} "
                       f"| | | | | {r.get('notes', '')[:60]} |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | **{r['dominant']}** | "
            f"{r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} | "
            f"{r['t_collective_s']:.3e} | {r['useful_ratio']:.3f} | "
            f"{hints[(r['dominant'],)]} |")
    return "\n".join(out)


def serving_section(results="benchmarks/results"):
    """Latency/throughput tables from BENCH_serving.json — the deploy-side
    trajectory next to the engine and wire sections."""
    path = os.path.join(results, "BENCH_serving.json")
    if not os.path.exists(path):
        return "(no BENCH_serving.json — run benchmarks.serving_bench)"
    r = json.load(open(path))
    out = [f"Device: {r.get('device', '?')} · max-wait "
           f"{r.get('max_wait_ms', '?')} ms · pre-lowered buckets "
           f"{r.get('buckets', [])} (fresh compiles in steady state: 0, "
           "asserted)\n",
           "### Batch sweep (closed loop, per-dispatch)\n",
           "| family | bucket | p50 ms | p99 ms | images/s |",
           "|---|---|---|---|---|"]
    for row in r.get("batch_sweep", []):
        out.append(f"| {row['family']} | {row['bucket']} | "
                   f"{row['p50_ms']:.2f} | {row['p99_ms']:.2f} | "
                   f"{row['images_per_sec']:.0f} |")
    out += ["\n### Arrival sweep (open loop, per-request through the "
            "batching queue)\n",
            "| family | rate req/s | p50 ms | p99 ms | achieved req/s | "
            "mean batch |",
            "|---|---|---|---|---|---|"]
    for row in r.get("arrival_sweep", []):
        out.append(f"| {row['family']} | {row['rate_rps']:g} | "
                   f"{row['p50_ms']:.2f} | {row['p99_ms']:.2f} | "
                   f"{row['throughput_rps']:.1f} | "
                   f"{row['batch_n_mean']:.2f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all")
    args = ap.parse_args()
    if args.section in ("all", "repro"):
        text, _ = repro_section()
        print("## §Repro\n" + text)
    if args.section in ("all", "dryrun"):
        print("\n## §Dry-run\n" + dryrun_section())
    if args.section in ("all", "roofline"):
        print("\n## §Roofline\n" + roofline_section())
    if args.section in ("all", "serving"):
        print("\n## §Serving\n" + serving_section())


if __name__ == "__main__":
    main()
