"""Federated learning on the synthetic CXR task with the int8 cut-layer link
compressor ablation (beyond-paper; repro.kernels.act_compress).

Shows the paper's headline trade-off directly: FL moves model-sized bytes
per round; SL-family methods move activation-sized bytes per batch; the int8
compressor cuts the SL link bytes ~4x at negligible metric cost.

  PYTHONPATH=src python examples/federated_cxr.py
"""

import jax
import numpy as np

from repro import optim as O
from repro.core.comm import comm_per_epoch
from repro.core.partition import cnn_adapter
from repro.core.strategies import make_strategy
from repro.data.synthetic import make_cxr_clients
from repro.models.cnn import DenseNetConfig, build_densenet


def main():
    clients = make_cxr_clients(seed=0, train_per_client=64,
                               val_per_client=32, test_per_client=32,
                               image_size=32)
    cfg = DenseNetConfig(growth=8, blocks=(2, 4), stem_ch=16, cut_layer=2)
    adapter = cnn_adapter(build_densenet(cfg))
    eb = {k: v[:16] for k, v in clients[0].train.items()}
    n_tr = [len(c.train["label"]) for c in clients]
    n_va = [len(c.val["label"]) for c in clients]

    print("per-epoch communication (analytic, paper Table 4 analogue):")
    profiles = {}
    for method in ["fl", "sl_ac", "sflv3_ac"]:
        c = profiles[method] = comm_per_epoch(method, adapter, eb, n_tr,
                                              n_va, 16)
        print(f"  {method:10s} {c.gb * 1e3:8.2f} MB   {c.breakdown}")
    from repro.wire import make_codec
    raw = profiles["sl_ac"]
    c8 = comm_per_epoch("sl_ac", adapter, eb, n_tr, n_va, 16,
                        codec=make_codec("int8"))
    print(f"  sl_ac+int8 {c8.gb * 1e3:8.2f} MB   "
          f"(cut-layer tensors int8+row-scale via repro.wire, "
          f"{raw.bytes_per_epoch / c8.bytes_per_epoch:.2f}x)")

    print("\ntraining FL for 4 rounds:")
    strat = make_strategy("fl", adapter, lambda: O.adam(3e-4), len(clients))
    state = strat.setup(jax.random.key(0))
    rng = np.random.default_rng(0)
    for r in range(4):
        state, log = strat.run_epoch(state, [c.train for c in clients],
                                     rng, 16)
        m = strat.evaluate(state, clients, "val", 32)
        print(f"  round {r}: loss={log.mean_loss:.4f} "
              f"val_auroc={m['auroc']:.3f}")
    print("test:", strat.evaluate(state, clients, "test", 32))


if __name__ == "__main__":
    main()
