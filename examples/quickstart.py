"""Quickstart: train a SplitFedv3 model (the paper's method) across five
virtual hospitals on the synthetic chest-X-ray task, then compare with plain
split learning — all on CPU in ~2 minutes.

  PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import numpy as np

from repro import optim as O
from repro.core.partition import cnn_adapter
from repro.core.strategies import make_strategy
from repro.data.synthetic import make_cxr_clients
from repro.models.cnn import DenseNetConfig, build_densenet
from repro.obs import Telemetry


def main():
    # five hospitals, non-IID scanners (see repro/data/synthetic.py)
    clients = make_cxr_clients(seed=0, train_per_client=64,
                               val_per_client=32, test_per_client=32,
                               image_size=32)
    cfg = DenseNetConfig(growth=8, blocks=(2, 4), stem_ch=16, cut_layer=2)

    for method in ["sflv3_ac", "sl_ac"]:
        adapter = cnn_adapter(build_densenet(cfg))
        # the default compiled engine lowers the WHOLE 4-epoch run into
        # one XLA program via strat.run (engine="stepwise" is the legacy
        # per-batch host loop; both train identically).  observe= taps
        # per-round telemetry (grad/update norms, cut-layer activation
        # stats) inside that one program — params stay bit-identical.
        # For cross-device federations, participation=Participation(
        # n_global=N, k=K) trains a K-hospital cohort per round (still
        # one program; compute scales with K, not N) — see DESIGN.md §14.
        strat = make_strategy(method, adapter, lambda: O.adam(3e-4),
                              n_clients=len(clients),
                              observe=Telemetry())
        state = strat.setup(jax.random.key(0))
        rng = np.random.default_rng(0)
        t0 = time.time()
        state, logs = strat.run(state, [c.train for c in clients], rng,
                                batch_size=16, n_epochs=4)
        for epoch, log in enumerate(logs):
            print(f"[{method}] epoch {epoch}: loss={log.mean_loss:.4f}")
        print(f"[{method}] per-round telemetry "
              "(hospital means; see repro.obs):")
        print(strat.last_run_telemetry.table())
        metrics = strat.evaluate(state, clients, "test", batch_size=32)
        print(f"[{method}] test {metrics}  ({time.time() - t0:.0f}s)\n")

    # serve the result: export hospital 0's deployable model (its own
    # front + the shared server, stitched at the cut) into a batched
    # screening service — pre-lowered bucket ladder, so steady-state
    # requests never compile; scores are bit-identical to strat.scores.
    # See DESIGN.md §15 and examples/train_and_serve.py for hot-swapping
    # each round's export into a live service.
    from repro.serving import ScreeningService
    servable = strat.export(state, client_idx=0)
    with ScreeningService(servable, image_shape=(32, 32, 1),
                          max_wait_s=0.002) as svc:
        score = svc.score_one({"image": clients[0].test["image"][0]})
        print(f"[serve] {servable.family} export v{svc.version}: "
              f"first test image scores {score:.4f} "
              f"(p50 {svc.stats()['total_p50_ms']:.2f} ms)")


if __name__ == "__main__":
    main()
