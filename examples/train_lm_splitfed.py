"""End-to-end driver (deliverable b): train a ~small LM with SplitFedv3 for a
few hundred steps on a synthetic Markov token stream and watch the loss fall.

The same ``make_sflv3_train_step`` lowers onto the 256-chip production mesh
in the dry-run; here it runs on CPU with 4 virtual hospitals.

  PYTHONPATH=src python examples/train_lm_splitfed.py [--steps 200]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim as O
from repro.data.synthetic import lm_clients
from repro.launch.train import init_sflv3_params, make_sflv3_train_step
from repro.models.transformer import ModelConfig, TransformerLM
from repro.train import checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)    # per client
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt", default="/tmp/repro_sflv3_lm.msgpack")
    args = ap.parse_args()

    cfg = ModelConfig(name="quick-lm", arch_type="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=256, cut_layer=1, remat=False,
                      compute_dtype=jnp.float32)
    model = TransformerLM.build(cfg)
    params, _ = init_sflv3_params(model, jax.random.key(0), args.clients)
    opt = O.adam(O.wsd(3e-3, warmup=20, stable=args.steps // 2,
                       decay=args.steps // 2))
    opt_state = opt.init(params)
    step = jax.jit(make_sflv3_train_step(model, opt, args.clients))

    data = lm_clients(seed=0, vocab=cfg.vocab_size,
                      n_clients=args.clients, seqs_per_client=256,
                      seq_len=args.seq + 1)
    rng = np.random.default_rng(0)

    t0 = time.time()
    for i in range(args.steps):
        toks = np.stack([d[rng.integers(0, len(d), args.batch)]
                         for d in data])            # (C, B, S+1)
        batch = {"tokens": jnp.asarray(
            toks.reshape(args.clients * args.batch, args.seq + 1))}
        params, opt_state, loss = step(params, opt_state, batch)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss={float(loss):.4f}  "
                  f"({time.time() - t0:.0f}s)", flush=True)
    checkpoint.save(args.ckpt, params)
    print(f"saved checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
