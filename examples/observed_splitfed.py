"""Observed training: every strategy of the paper's grid run with full
telemetry, merged into ONE Chrome-trace/Perfetto JSON.

Each of the five distributed strategies (FL, SL alternate-minibatch,
SFLv1/v2/v3) trains the synthetic multi-hospital CXR task as ONE compiled
dispatch with ``observe=Telemetry()`` — per-round x per-hospital train
loss, grad/update norms, the FedAvg update cosine, cut-layer activation
stats, DP clip fractions and the per-round RDP epsilon series ride the
whole-run scan as extra outputs (params bit-identical to an unobserved
run; tests/test_obs.py).  Around the dispatch, a ``Tracer`` records the
host phases (pack -> dispatch), and the wire simulator replays each
method's transfers over the hospital WAN into per-client timelines.

All three views land in one ``trace_observed.json`` — engine-host lanes
(with synthetic per-round slices carrying the telemetry and epsilon
counter tracks), one simulated-wire lane per strategy — loadable in
chrome://tracing or https://ui.perfetto.dev.  Per strategy it also writes
``RUNLOG_<method>.json`` (telemetry + cost summary: dispatch count,
compile seconds, HLO flop/byte estimates) and a markdown report.

  PYTHONPATH=src python examples/observed_splitfed.py [--smoke]
      [--out OUT_DIR] [--epochs N] [--no-dp]
"""

import argparse
import json
import os
import time

import jax
import numpy as np

from repro import optim as O
from repro.core.partition import cnn_adapter
from repro.core.strategies import make_strategy
from repro.data.synthetic import make_cxr_clients
from repro.models.cnn import DenseNetConfig, build_densenet
from repro.obs import (Telemetry, Tracer, cost_summary, merge_events,
                       round_events, wire_events, write_chrome_trace,
                       write_runlog)
from repro.obs.report import write_report
from repro.privacy import PrivacyConfig
from repro.wire import Transport
from repro.wire.simulator import simulate, timeline_from_accounting

METHODS = ["fl", "sl_am", "sflv1_ac", "sflv2_ac", "sflv3_ac"]


def observe_one(method, adapter, clients, batch, epochs, privacy):
    """Train one strategy observed; return (telemetry, trace events,
    cost summary, wall seconds)."""
    transport = Transport("identity") if method != "fl" else None
    strat = make_strategy(method, adapter, lambda: O.adam(1e-3),
                          len(clients), transport=transport,
                          privacy=privacy, observe=Telemetry())
    tracer = strat.attach_tracer(Tracer())
    state = strat.setup(jax.random.key(0))
    data = [c.train for c in clients]
    t0 = time.perf_counter()
    state, logs = strat.run(state, data, np.random.default_rng(0), batch,
                            epochs)
    wall = time.perf_counter() - t0
    rt = strat.last_run_telemetry

    # engine-host lane: real spans + synthetic per-round slices of the
    # one dispatch, carrying telemetry args and epsilon counters
    events = tracer.trace_events()
    events += round_events(rt, tracer.find("dispatch"))

    # wire lane: simulated per-client transfer timelines.  Cut-layer
    # methods replay the transport's REAL recorded accounting; FL (no cut
    # traffic metered in-graph) models its round legs analytically.
    n_va = [len(c.val["label"]) for c in clients]
    if transport is not None:
        sim = timeline_from_accounting(transport, n_val=n_va,
                                       batch_size=batch)
    else:
        eb = {k: v[:1] for k, v in clients[0].train.items()}
        sim = simulate("fl", adapter, eb,
                       [len(c.train["label"]) for c in clients], n_va,
                       batch)
    events += wire_events(sim, label=method)

    steps = sum(l.steps for l in logs)
    cost = cost_summary(strat, wall_seconds=wall, total_steps=steps)
    cost["wire"] = {"bytes_on_wire": sim.bytes_on_wire,
                    "sim_wall_clock_s": sim.wall_clock_s}
    return rt, events, cost


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI (seconds, not minutes)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "out"))
    ap.add_argument("--epochs", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--no-dp", action="store_true",
                    help="train without DP-SGD (drops the epsilon "
                         "counter tracks)")
    args = ap.parse_args(argv)

    if args.smoke:
        clients = make_cxr_clients(seed=0, train_per_client=[17, 12, 9],
                                   val_per_client=6, test_per_client=7,
                                   image_size=16, n_clients=3)
        cfg = DenseNetConfig(growth=4, blocks=(1, 1), stem_ch=8,
                             cut_layer=1)
        batch, epochs = args.batch or 4, args.epochs or 2
    else:
        clients = make_cxr_clients(seed=0, train_per_client=64,
                                   val_per_client=16, test_per_client=16,
                                   image_size=32)
        cfg = DenseNetConfig(growth=8, blocks=(2, 2), stem_ch=16,
                             cut_layer=2)
        batch, epochs = args.batch or 16, args.epochs or 3
    adapter = cnn_adapter(build_densenet(cfg))
    privacy = (None if args.no_dp
               else PrivacyConfig(noise_multiplier=1.1, clip_norm=1.0))

    os.makedirs(args.out, exist_ok=True)
    merged = []
    for i, method in enumerate(METHODS):
        rt, events, cost = observe_one(method, adapter, clients, batch,
                                       epochs, privacy)
        # each strategy gets its own pid block so all five coexist in one
        # trace file
        merged += merge_events(events, pid_offset=10 * i)
        write_runlog(args.out, method, telemetry=rt, cost=cost)
        write_report(args.out, method, rt, cost=cost)
        last = rt.rounds[-1].scalars()
        print(f"[{method}] {epochs} rounds, ONE dispatch="
              f"{cost['dispatches'] == 1}, "
              f"loss={last.get('loss', float('nan')):.4f}"
              + (f", eps_max={last['epsilon_max']:.2f}"
                 if "epsilon_max" in last else ""))
        print(rt.table())
        print()

    path = write_chrome_trace(merged,
                              os.path.join(args.out,
                                           "trace_observed.json"))
    with open(path) as f:
        n_events = len(json.load(f)["traceEvents"])
    print(f"wrote {path} ({n_events} events, {len(METHODS)} strategies)")
    print(f"runlogs + reports in {args.out}/")


if __name__ == "__main__":
    main()
