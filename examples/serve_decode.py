"""Serving example: batched prefill + autoregressive decode with a KV cache,
on the SmolLM-family smoke config (and the mamba2 SSM family to show the
O(1)-state decode path).

  PYTHONPATH=src python examples/serve_decode.py
"""

import time

import jax

from repro.configs.registry import REGISTRY
from repro.models.transformer import TransformerLM
from repro.serving.engine import greedy_generate


def run(arch_id: str, batch=4, prompt_len=16, max_new=24):
    cfg = REGISTRY[arch_id].smoke
    model = TransformerLM.build(cfg)
    params = model.init_params(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (batch, prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.time()
    out = greedy_generate(model, params, prompt, max_new=max_new,
                          max_len=prompt_len + max_new)
    dt = time.time() - t0
    print(f"[{arch_id}] generated {out.shape} tokens in {dt:.1f}s "
          f"({batch * max_new / dt:.1f} tok/s on CPU)")
    print("  first row:", out[0].tolist())


def main():
    run("smollm-135m")         # dense GQA decode
    run("mamba2-130m")         # SSM recurrent decode (O(1) state)
    run("zamba2-7b")           # hybrid: SSM + shared-attention KV cache


if __name__ == "__main__":
    main()
