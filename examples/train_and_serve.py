"""Close the train→deploy loop: a live screening service absorbing each
federated round's model via zero-downtime hot-swap.

A ``ScreeningService`` starts serving after round 1 and keeps answering
single-image requests while FL training continues; after every round the
fresh ``Strategy.export`` is swapped in behind the in-flight-safe
``ModelSlot``.  At each round the script scores the pooled test set BOTH
ways — training-side ``Strategy.scores_all`` and request-by-request
through the live service — and asserts the AUROCs match BIT-exactly:
the service serves exactly the model training just produced, never a
stale or torn one.

The coda round-trips a SplitFedv3 export (client front stitched with the
shared server at the cut) through the on-disk checkpoint format and
re-serves it — the multi-hospital strategies deploy through the same
path as FL.

  PYTHONPATH=src python examples/train_and_serve.py
"""

import concurrent.futures as cf
import tempfile

import jax
import numpy as np

from repro import optim as O
from repro.core.partition import cnn_adapter
from repro.core.strategies import make_strategy
from repro.data.synthetic import make_cxr_clients
from repro.models.cnn import DenseNetConfig, build_densenet
from repro.serving import ScreeningService, load_servable, save_servable
from repro.train.metrics import auroc

ROUNDS = 4


def pooled_test(clients):
    return (np.concatenate([c.test["image"] for c in clients]),
            np.concatenate([c.test["label"] for c in clients]))


def serve_auroc(svc, images, labels):
    """Score the pooled test set one request at a time through the live
    queue (8 concurrent clients), like screening traffic would."""
    with cf.ThreadPoolExecutor(8) as ex:
        scores = list(ex.map(
            lambda im: svc.score_one({"image": im}), images))
    return auroc(labels, np.asarray(scores, np.float32))


def main():
    clients = make_cxr_clients(seed=0, train_per_client=64,
                               val_per_client=16, test_per_client=32,
                               image_size=32)
    cfg = DenseNetConfig(growth=8, blocks=(2, 2), stem_ch=16, cut_layer=2)
    adapter = cnn_adapter(build_densenet(cfg))
    strat = make_strategy("fl", adapter, lambda: O.adam(3e-4),
                          n_clients=len(clients))
    state = strat.setup(jax.random.key(0))
    rng = np.random.default_rng(0)
    images, labels = pooled_test(clients)

    svc = None
    try:
        for rnd in range(ROUNDS):
            state, logs = strat.run(state, [c.train for c in clients], rng,
                                    batch_size=16, n_epochs=1)
            servable = strat.export(state, meta={"round": rnd})
            if svc is None:
                svc = ScreeningService(servable,
                                       image_shape=images.shape[1:],
                                       max_wait_s=0.002)
            else:
                svc.swap(servable)           # zero downtime: in-flight
                                             # requests finish on the old
                                             # tree, new ones see round rnd
            # training-side eval (FL: same global model for every client)
            train_scores = np.concatenate(
                strat.scores_all(state, [c.test for c in clients]))
            train_auroc = auroc(labels, train_scores)
            live_auroc = serve_auroc(svc, images, labels)
            assert live_auroc == train_auroc, (
                f"round {rnd}: served AUROC {live_auroc} != training eval "
                f"{train_auroc} — the service is not bit-exact")
            st = svc.stats()
            print(f"round {rnd}: loss={logs[-1].mean_loss:.4f}  "
                  f"AUROC train={train_auroc:.4f} "
                  f"serve={live_auroc:.4f} (bit-exact, v{svc.version})  "
                  f"p50={st['total_p50_ms']:.2f}ms "
                  f"p99={st['total_p99_ms']:.2f}ms")
    finally:
        if svc is not None:
            svc.close()

    # -- the split family deploys through the same path -------------------
    sfl = make_strategy("sflv3_ac", adapter, lambda: O.adam(3e-4),
                        n_clients=len(clients))
    sstate = sfl.setup(jax.random.key(1))
    sstate, _ = sfl.run(sstate, [c.train for c in clients], rng,
                        batch_size=16, n_epochs=1)
    ref = np.asarray(sfl.scores(sstate, 2, clients[2].test))
    with tempfile.NamedTemporaryFile(suffix=".msgpack") as f:
        save_servable(f.name, sfl.export(sstate, client_idx=2,
                                         meta={"round": 0}))
        servable = load_servable(f.name, adapter)
    with ScreeningService(servable, image_shape=images.shape[1:]) as svc2:
        got = np.asarray([svc2.score_one({"image": im})
                          for im in clients[2].test["image"]], np.float32)
    assert np.array_equal(got, ref.ravel())
    print(f"sflv3 export (hospital 2 front + shared server) round-tripped "
          f"through {servable.family!r} checkpoint and re-served "
          f"bit-exactly (AUROC {auroc(clients[2].test['label'], got):.4f})")


if __name__ == "__main__":
    main()
