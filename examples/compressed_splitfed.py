"""SplitFedv3 with a compressed cut-layer link (repro.wire Transport).

Trains the paper's proposed SFLv3 on the synthetic 5-hospital CXR task
twice — once over an uncompressed link and once with the int8 Pallas codec
roundtripping every cut-layer tensor in-graph — and reports AUROC next to
the achieved on-wire compression ratio, plus the simulated epoch wall-clock
over the hospital WAN for each codec.  This is the operational form of the
paper's Table-4 finding: the SL family's bytes are activations, so codecs
on the cut layer buy back wall-clock that FL can only get from smaller
models.

  PYTHONPATH=src python examples/compressed_splitfed.py [--epochs N]
"""

import argparse

import jax
import numpy as np

from repro import optim as O
from repro.core.partition import cnn_adapter
from repro.core.strategies import make_strategy
from repro.data.synthetic import make_cxr_clients
from repro.models.cnn import DenseNetConfig, build_densenet
from repro.wire import Transport, boundary_error, simulate


def train(method, adapter, clients, epochs, codec=None, seed=0):
    transport = Transport(codec) if codec else None
    strat = make_strategy(method, adapter, lambda: O.adam(3e-4),
                          len(clients), transport=transport)
    state = strat.setup(jax.random.key(seed))
    rng = np.random.default_rng(seed)
    log = None
    for _ in range(epochs):
        state, log = strat.run_epoch(state, [c.train for c in clients],
                                     rng, 16)
    metrics = strat.evaluate(state, clients, "test", 32)
    return state, strat, metrics, log, transport


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--method", default="sflv3_ac")
    args = ap.parse_args(argv)

    clients = make_cxr_clients(seed=0, train_per_client=96,
                               val_per_client=32, test_per_client=48,
                               image_size=32)
    cfg = DenseNetConfig(growth=8, blocks=(2, 4), stem_ch=16, cut_layer=2)
    adapter = cnn_adapter(build_densenet(cfg))

    print(f"{args.method} on 5 synthetic hospitals, {args.epochs} epochs\n")
    rows = []
    for codec in (None, "int8"):
        label = codec or "identity"
        state, strat, m, log, tp = train(args.method, adapter, clients,
                                         args.epochs, codec)
        ratio = tp.compression_ratio if tp else 1.0
        wire_mb = tp.bytes_on_wire / 1e6 if tp else float("nan")
        rows.append((label, m["auroc"], m["auprc"], ratio))
        print(f"  codec={label:8s} loss={log.mean_loss:.4f} "
              f"test_auroc={m['auroc']:.3f} test_auprc={m['auprc']:.3f} "
              f"compression={ratio:.2f}x"
              + (f" wire={wire_mb:.1f} MB" if tp else ""))
        if tp:
            params = strat.params_for_eval(state, 0)
            batch = {k: v[:16] for k, v in clients[0].train.items()}
            errs = boundary_error(tp, adapter, params, batch)
            rel = [e["rel_l2"] for v in errs.values() for e in v]
            print(f"           cut-layer rel-L2 reconstruction error: "
                  f"{max(rel):.4f}")

    base, comp = rows[0], rows[1]
    print(f"\n  AUROC delta (int8 - identity): {comp[1] - base[1]:+.4f} "
          f"at {comp[3]:.2f}x fewer bytes on the wire")

    eb = {k: v[:16] for k, v in clients[0].train.items()}
    n_tr = [len(c.train["label"]) for c in clients]
    n_va = [len(c.val["label"]) for c in clients]
    print("\nsimulated epoch wall-clock over hospital_wan:")
    for codec in ("identity", "bf16", "int8", "topk:0.1"):
        r = simulate(args.method, adapter, eb, n_tr, n_va, 16, codec,
                     "hospital_wan", keep_events=False)
        print(f"  {codec:9s} {r.bytes_on_wire / 1e6:8.2f} MB  "
              f"{r.wall_clock_s:6.2f} s")


if __name__ == "__main__":
    main()
