"""SplitFedv3 under differential privacy (repro.privacy).

Trains the paper's proposed SFLv3 on the synthetic 5-hospital CXR task
three ways —

  * non-private (the paper's regime),
  * DP-SGD: per-example clip + Gaussian noise via the fused Pallas kernel,
    with the RDP accountant reporting per-hospital (eps, delta),
  * cut-layer noise: Gaussian noise on the smashed activations only
    (Li et al.'s mitigation; no gradient accounting, but directly attacks
    the No-Peek server-inference channel)

— and reports AUROC next to what an honest-but-curious server can still
extract from the cut layer: distance correlation with the raw inputs and a
linear reconstruction probe's held-out R^2, measured on exactly what
crosses the wire.

  PYTHONPATH=src python examples/private_splitfed.py [--epochs N]
"""

import argparse
import math

import jax
import numpy as np

from repro import optim as O
from repro.core.partition import cnn_adapter
from repro.core.strategies import make_strategy
from repro.data.synthetic import make_cxr_clients
from repro.models.cnn import DenseNetConfig, build_densenet
from repro.privacy import PrivacyConfig, measure_leakage


def train(adapter, clients, epochs, privacy, batch_size=16, seed=0):
    strat = make_strategy("sflv3_ac", adapter, lambda: O.adam(3e-4),
                          len(clients), privacy=privacy)
    state = strat.setup(jax.random.key(seed))
    rng = np.random.default_rng(seed)
    log = None
    for _ in range(epochs):
        state, log = strat.run_epoch(state, [c.train for c in clients],
                                     rng, batch_size)
    metrics = strat.evaluate(state, clients, "test", 32)
    return strat, state, metrics, log


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--sigma", type=float, default=1.0)
    ap.add_argument("--clip", type=float, default=1.0)
    ap.add_argument("--cut-noise", type=float, default=0.5)
    args = ap.parse_args(argv)

    clients = make_cxr_clients(seed=0, train_per_client=[96, 192, 48, 96,
                                                         48],
                               val_per_client=32, test_per_client=48,
                               image_size=32)
    cfg = DenseNetConfig(growth=8, blocks=(2, 4), stem_ch=16, cut_layer=2)
    adapter = cnn_adapter(build_densenet(cfg))

    regimes = [
        ("non-private", None),
        (f"dp-sgd s={args.sigma:g} C={args.clip:g}",
         PrivacyConfig(noise_multiplier=args.sigma, clip_norm=args.clip)),
        (f"cut-noise std={args.cut_noise:g}",
         PrivacyConfig(cut_noise_std=args.cut_noise)),
    ]

    print(f"sflv3_ac on 5 synthetic hospitals, {args.epochs} epochs\n")
    dp_strat = None
    for label, privacy in regimes:
        strat, state, m, log = train(adapter, clients, args.epochs, privacy)
        if privacy is not None and privacy.dp_enabled:
            dp_strat = strat
        params = strat.params_for_eval(state, 0)
        probe_batch = {k: v[:64] for k, v in clients[0].test.items()}
        leak = measure_leakage(adapter, params, probe_batch,
                               privacy=privacy)
        report = strat.privacy_report()
        if report:
            eps = max(r["epsilon"] for r in report)
            eps_s = ("inf" if math.isinf(eps)
                     else f"{eps:.2f} (delta={report[0]['delta']:g})")
        else:
            eps_s = "-"
        print(f"  {label:24s} loss={log.mean_loss:.4f} "
              f"auroc={m['auroc']:.3f} sens={m['sensitivity']:.2f} "
              f"spec={m['specificity']:.2f}")
        print(f"  {'':24s} eps={eps_s}  "
              f"cut-layer dCor={leak['dcor_input']:.3f} "
              f"probe R2={leak['probe']['r2']:.3f}\n")

    if dp_strat is not None:
        print("per-hospital accountants (unequal data => unequal eps):")
        for i, r in enumerate(dp_strat.privacy_report()):
            print(f"  DT{i + 1}: eps={r['epsilon']:.2f} "
                  f"steps={r['steps']}")


if __name__ == "__main__":
    main()
