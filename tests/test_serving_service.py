"""Train→deploy loop: export bit-exactness per strategy x precision,
checkpoint round-trip, chunked-eval parity, zero-compile steady state,
torn-swap safety, and the batching front end."""

import concurrent.futures as cf
import threading

import jax
import numpy as np
import pytest

from repro import optim as O
from repro.core.partition import cast_adapter, cnn_adapter
from repro.core.strategies import make_strategy
from repro.data.synthetic import make_cxr_clients
from repro.models.cnn import DenseNetConfig, build_densenet
from repro.serving import (Backpressure, BucketScorer, ScreeningService,
                           load_servable, save_servable)

METHODS = ["centralized", "fl", "sl_ac", "sl_am", "sflv2_ac", "sflv3_ac",
           "sflv1_ac"]


@pytest.fixture(scope="module")
def tiny_setup():
    clients = make_cxr_clients(seed=0, train_per_client=[12, 9, 8],
                               val_per_client=4, test_per_client=13,
                               image_size=16, n_clients=3)
    cfg = DenseNetConfig(growth=4, blocks=(1, 1), stem_ch=8, cut_layer=1)
    return clients, cfg


def _trained(method, clients, cfg, precision="fp32"):
    adapter = cnn_adapter(build_densenet(cfg))
    if precision == "bf16":
        adapter = cast_adapter(adapter, precision)
    st = make_strategy(method, adapter, lambda: O.adam(1e-3), len(clients))
    state = st.setup(jax.random.key(0))
    state, _ = st.run_epoch(state, [c.train for c in clients],
                            np.random.default_rng(0), 4)
    return st, state, adapter


# -- export ------------------------------------------------------------------

@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("precision", ["fp32", "bf16"])
def test_export_scores_bitexact(method, precision, tiny_setup):
    """Exported model scores == Strategy.scores to the BIT, for every
    strategy and both training precisions (the per-client-head variants
    must stitch the right front/tail per hospital)."""
    clients, cfg = tiny_setup
    st, state, _ = _trained(method, clients, cfg, precision)
    for i in range(len(clients)):
        ref = np.asarray(st.scores(state, i, clients[i].test, batch_size=5))
        sv = st.export(state, client_idx=i)
        got = np.asarray(sv.scores(clients[i].test, batch_size=5))
        np.testing.assert_array_equal(ref, got)
        assert sv.meta["strategy"] == st.name


def test_export_distinct_heads(tiny_setup):
    """Per-client-head strategies export DIFFERENT models per hospital."""
    clients, cfg = tiny_setup
    st, state, _ = _trained("sflv3_ac", clients, cfg)
    s0 = np.asarray(st.export(state, 0).scores(clients[0].test, 5))
    s1 = np.asarray(st.export(state, 1).scores(clients[0].test, 5))
    assert not np.array_equal(s0, s1)


def test_checkpoint_roundtrip(tmp_path, tiny_setup):
    clients, cfg = tiny_setup
    st, state, adapter = _trained("sflv3_ac", clients, cfg)
    sv = st.export(state, client_idx=2, meta={"round": 7})
    p = str(tmp_path / "model.msgpack")
    save_servable(p, sv)
    sv2 = load_servable(p, adapter)
    assert sv2.meta["strategy"] == "sflv3_ac"
    assert sv2.meta["round"] == 7 and sv2.meta["client_idx"] == 2
    assert sv2.shared == sv.shared
    np.testing.assert_array_equal(
        np.asarray(sv.scores(clients[2].test, 5)),
        np.asarray(sv2.scores(clients[2].test, 5)))


def test_load_servable_missing_keys(tmp_path, tiny_setup):
    clients, cfg = tiny_setup
    st, state, _ = _trained("fl", clients, cfg)
    sv = st.export(state)
    p = str(tmp_path / "model.msgpack")
    save_servable(p, sv)
    other = cnn_adapter(build_densenet(
        DenseNetConfig(growth=8, blocks=(1, 1), stem_ch=8, cut_layer=1)))
    with pytest.raises(ValueError, match="mismatch"):
        load_servable(p, other)


# -- chunked eval ------------------------------------------------------------

@pytest.mark.parametrize("method", ["fl", "sflv3_ac"])
def test_chunked_eval_parity(method, tiny_setup):
    """Chunked scores/scores_all == the single-dispatch path <= 1e-5
    (empirically bit-equal: same vmapped program per example)."""
    clients, cfg = tiny_setup
    st, state, _ = _trained(method, clients, cfg)
    datas = [c.test for c in clients]
    ref_all = st.scores_all(state, datas, batch_size=4)
    for ch in (1, 2, 100):
        got_all = st.scores_all(state, datas, batch_size=4,
                                chunk_batches=ch)
        for r, g in zip(ref_all, got_all):
            np.testing.assert_allclose(r, g, atol=1e-5)
    ref = np.asarray(st.scores(state, 1, datas[1], batch_size=4))
    got = np.asarray(st.scores(state, 1, datas[1], batch_size=4,
                               chunk_batches=2))
    np.testing.assert_allclose(ref, got, atol=1e-5)


# -- scoring core ------------------------------------------------------------

def test_scorer_zero_fresh_compiles(tiny_setup):
    """Every request size (including > largest bucket) routes through the
    pre-lowered ladder; n_compiles never moves after construction."""
    clients, cfg = tiny_setup
    st, state, _ = _trained("fl", clients, cfg)
    sv = st.export(state)
    img = clients[0].test["image"]
    sc = BucketScorer(sv, image_shape=img.shape[1:], buckets=(1, 2, 4))
    built = sc.n_compiles
    assert built == 3
    ref = np.asarray(st.scores(state, 0, clients[0].test, batch_size=5))
    for n in (1, 2, 3, 4, 5, 9, 13):
        got, info = sc.score({"image": img[:n]})
        np.testing.assert_array_equal(got, ref[:n].ravel())
        assert info["n_dispatch"] == -(-n // 4)
    assert sc.n_compiles == built
    assert sc.n_dispatches > 0


def test_scorer_bf16_precision(tiny_setup):
    clients, cfg = tiny_setup
    st, state, _ = _trained("fl", clients, cfg)
    sv = st.export(state)
    img = clients[0].test["image"]
    sc = BucketScorer(sv, image_shape=img.shape[1:], buckets=(4,),
                      precision="bf16")
    got, _ = sc.score({"image": img[:4]})
    ref = np.asarray(st.scores(state, 0, clients[0].test, batch_size=5))[:4]
    assert got.dtype == np.float32
    np.testing.assert_allclose(got, ref.ravel(), atol=0.05)
    with pytest.raises(ValueError):
        BucketScorer(sv, image_shape=img.shape[1:], precision="fp8")


def test_swap_rejects_mismatched_tree(tiny_setup):
    clients, cfg = tiny_setup
    st, state, _ = _trained("fl", clients, cfg)
    sv = st.export(state)
    sc = BucketScorer(sv, image_shape=clients[0].test["image"].shape[1:],
                      buckets=(1,))
    with pytest.raises(ValueError):
        sc.swap({"front": sv.params["front"]})
    with pytest.raises(ValueError):
        sc.swap(jax.tree.map(lambda l: np.zeros((3,), np.float32),
                             sv.params))


def test_swap_never_serves_torn_tree(tiny_setup):
    """Hammer score() from threads while swapping between two param sets
    whose full-model scores differ everywhere: every served score must be
    bit-equal to ONE of the two models' scores — a torn (half-old/half-new)
    tree would produce a third value."""
    clients, cfg = tiny_setup
    st, state, _ = _trained("fl", clients, cfg)
    sv0 = st.export(state)
    state2, _ = st.run_epoch(state, [c.train for c in clients],
                             np.random.default_rng(1), 4)
    sv1 = st.export(state2)
    img = clients[0].test["image"][:4]
    sc = BucketScorer(sv0, image_shape=img.shape[1:], buckets=(4,))
    a, _ = sc.score({"image": img})
    sc.swap(sv1)
    b, _ = sc.score({"image": img})
    assert not np.array_equal(a, b)

    stop = threading.Event()

    def swapper():
        flip = 0
        while not stop.is_set():
            sc.swap(sv1 if flip % 2 == 0 else sv0)
            flip += 1

    t = threading.Thread(target=swapper)
    t.start()
    try:
        with cf.ThreadPoolExecutor(4) as ex:
            outs = list(ex.map(
                lambda _: sc.score({"image": img})[0], range(60)))
    finally:
        stop.set()
        t.join()
    for got in outs:
        assert np.array_equal(got, a) or np.array_equal(got, b)
    assert sc.version >= 2


# -- batching front end ------------------------------------------------------

def test_service_batches_and_matches_eval(tiny_setup):
    clients, cfg = tiny_setup
    st, state, _ = _trained("fl", clients, cfg)
    sv = st.export(state)
    data = clients[0].test
    ref = np.asarray(st.scores(state, 0, data, batch_size=5)).ravel()
    with ScreeningService(sv, image_shape=data["image"].shape[1:],
                          buckets=(1, 2, 4), max_wait_s=0.002,
                          trace=True) as svc:
        with cf.ThreadPoolExecutor(8) as ex:
            got = list(ex.map(
                lambda i: svc.score_one({"image": data["image"][i]}),
                range(len(ref))))
        stats = svc.stats()
        events = svc.trace_events()
    np.testing.assert_array_equal(np.asarray(got, np.float32), ref)
    assert stats["n"] == len(ref)
    assert stats["total_p99_ms"] >= stats["total_p50_ms"] >= 0
    # per-request queue spans + batch phase spans made it into the trace
    names = {e["name"] for e in events}
    assert {"queue_wait", "dispatch", "pad", "readback"} <= names
    assert sum(e["name"] == "queue_wait" for e in events) == len(ref)


def test_service_backpressure(tiny_setup):
    clients, cfg = tiny_setup
    st, state, _ = _trained("fl", clients, cfg)
    sv = st.export(state)
    img = clients[0].test["image"]
    # queue cap (3) below the only bucket (4): the dispatcher can't fire
    # before max_wait, so the 4th submission deterministically sheds
    with ScreeningService(sv, image_shape=img.shape[1:], buckets=(4,),
                          max_wait_s=0.3, max_queue=3) as svc:
        reqs = [svc.submit({"image": img[0]}) for _ in range(3)]
        with pytest.raises(Backpressure):
            svc.submit({"image": img[0]})
        # shed requests don't poison the queue: the waiting three still
        # complete as one padded dispatch once max_wait expires
        for r in reqs:
            assert r.done.wait(5)


def test_service_hot_swap_versions(tiny_setup):
    clients, cfg = tiny_setup
    st, state, _ = _trained("fl", clients, cfg)
    # export BEFORE the next round: strategies update state in place, so
    # the snapshot must be materialized when it is current
    sv0 = st.export(state)
    state2, _ = st.run_epoch(state, [c.train for c in clients],
                             np.random.default_rng(1), 4)
    img = clients[0].test["image"]
    with ScreeningService(sv0, image_shape=img.shape[1:],
                          buckets=(1,), max_wait_s=0.0) as svc:
        s0 = svc.score_one({"image": img[0]})
        assert svc.version == 0
        svc.swap(st.export(state2))
        assert svc.version == 1
        s1 = svc.score_one({"image": img[0]})
    assert s0 != s1
