"""Observability tests (repro.obs): telemetry taps riding the compiled
scans, span tracing, profiling summaries, and run reports.

The load-bearing acceptance properties:

  * telemetry-ON params are BIT-identical to telemetry-OFF — observation
    never draws keys or reorders math;
  * an observed compiled ``Strategy.run`` is still ONE dispatch;
  * both engines reduce to the same per-round x per-hospital telemetry
    (the stepwise oracle collects the same taps per step);
  * per-hospital metric rows are un-padded (no phantom hospitals) and the
    per-round epsilon series terminates at the accountant's epsilons.
"""

import json

import jax
import numpy as np
import pytest

from repro import optim as O
from repro.core.partition import cnn_adapter
from repro.core.strategies import make_strategy
from repro.data.synthetic import make_cxr_clients
from repro.models.cnn import DenseNetConfig, build_densenet
from repro.obs import Telemetry
from repro.obs.telemetry import as_telemetry
from repro.privacy import PrivacyConfig

METHODS = ["fl", "centralized", "sl_am", "sflv2_ac", "sflv3_ac",
           "sflv1_ac"]
ENGINES = ["compiled", "stepwise"]
CUT_METHODS = {"sl_am", "sflv2_ac", "sflv3_ac", "sflv1_ac"}
DP = PrivacyConfig(noise_multiplier=1.1, clip_norm=1.0)
EPOCHS = 2


@pytest.fixture(scope="module")
def tiny_setup():
    clients = make_cxr_clients(seed=0, train_per_client=[17, 12, 9],
                               val_per_client=6, test_per_client=7,
                               image_size=16, n_clients=3)
    cfg = DenseNetConfig(growth=4, blocks=(1, 1), stem_ch=8, cut_layer=1)
    return clients, cnn_adapter(build_densenet(cfg))


# one (method, engine, observed, privacy) run each — shared across tests
_CACHE = {}


def _leaves(st, state):
    return [np.asarray(l) for i in range(3)
            for l in jax.tree.leaves(st.params_for_eval(state, i))]


def _run(tiny_setup, method, engine, observed, privacy=None, shard=False):
    key = (method, engine, observed, privacy is not None, shard)
    if key not in _CACHE:
        clients, adapter = tiny_setup
        st = make_strategy(method, adapter, lambda: O.adam(1e-3),
                           len(clients), privacy=privacy, engine=engine,
                           shard=shard,
                           observe=Telemetry() if observed else None)
        state = st.setup(jax.random.key(0))
        state, logs = st.run(state, [c.train for c in clients],
                             np.random.default_rng(0), 4, EPOCHS)
        _CACHE[key] = {"st": st, "leaves": _leaves(st, state),
                       "logs": logs, "rt": st.last_run_telemetry,
                       "dispatches": st._dispatches}
    return _CACHE[key]


# ---------------------------------------------------------------------------
# acceptance: bit-identical params, one dispatch, correct taps
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("method", METHODS)
def test_observed_params_bit_identical(method, engine, tiny_setup):
    """Enabling telemetry changes NOTHING about the training math — every
    parameter leaf is bit-for-bit the unobserved run's."""
    off = _run(tiny_setup, method, engine, observed=False)
    on = _run(tiny_setup, method, engine, observed=True)
    assert len(off["leaves"]) == len(on["leaves"])
    for a, b in zip(off["leaves"], on["leaves"]):
        np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("method", METHODS)
def test_observed_compiled_run_is_one_dispatch(method, tiny_setup):
    """The metric taps ride the whole-run scan as extra outputs: an
    observed multi-epoch compiled run is still ONE program invocation."""
    on = _run(tiny_setup, method, "compiled", observed=True)
    assert on["dispatches"] == 1
    assert getattr(on["st"], "_run_calls", 0) == 1


@pytest.mark.parametrize("method", METHODS)
def test_round_telemetry_content(method, tiny_setup):
    """Per-round telemetry: one RoundTelemetry per epoch, the right tap
    set for the method family, un-padded [n_hospitals] rows."""
    rt = _run(tiny_setup, method, "compiled", observed=True)["rt"]
    assert rt is not None and rt.strategy != ""
    assert len(rt.rounds) == EPOCHS
    n_rows = 1 if method == "centralized" else 3   # pooled vs per-hospital
    for i, r in enumerate(rt.rounds):
        assert r.round_index == i
        keys = set(r.metrics)
        assert {"loss", "grad_norm", "update_norm"} <= keys
        assert ("update_cosine" in keys) == (method == "fl")
        assert ({"cut_mean", "cut_std", "cut_absmax"} <= keys) == (
            method in CUT_METHODS)
        assert "clip_frac" not in keys            # no DP in this run
        assert r.epsilon is None
        for k, v in r.metrics.items():
            v = np.asarray(v)
            assert v.shape == (n_rows,), (k, v.shape)
            assert np.isfinite(v).all(), (k, v)
    # logs carry the same objects
    logs = _run(tiny_setup, method, "compiled", observed=True)["logs"]
    assert [l.telemetry for l in logs] == rt.rounds


@pytest.mark.parametrize("method", METHODS)
def test_telemetry_engine_parity(method, tiny_setup):
    """Both engines reduce to the same per-round x per-hospital values
    (the stepwise oracle taps the same intermediates per step)."""
    rc = _run(tiny_setup, method, "compiled", observed=True)["rt"]
    rs = _run(tiny_setup, method, "stepwise", observed=True)["rt"]
    assert len(rc.rounds) == len(rs.rounds)
    for a, b in zip(rc.rounds, rs.rounds):
        assert set(a.metrics) == set(b.metrics)
        for k in a.metrics:
            np.testing.assert_allclose(a.metrics[k], b.metrics[k],
                                       atol=1e-4, err_msg=k)


def test_fl_update_cosine_bounds(tiny_setup):
    """The FedAvg update cosine is a true cosine: in [-1, 1], and with
    one hospital-weighted mean over three hospitals, not all 1."""
    rt = _run(tiny_setup, "fl", "compiled", observed=True)["rt"]
    cos = rt.metric("update_cosine")
    assert cos.shape == (EPOCHS, 3)
    assert (np.abs(cos) <= 1.0 + 1e-6).all()


# ---------------------------------------------------------------------------
# DP runs: clip fractions + per-round epsilon series
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["fl", "sl_am"])
def test_observed_dp_run(method, tiny_setup):
    off = _run(tiny_setup, method, "compiled", observed=False, privacy=DP)
    on = _run(tiny_setup, method, "compiled", observed=True, privacy=DP)
    for a, b in zip(off["leaves"], on["leaves"]):
        np.testing.assert_array_equal(a, b)
    rt = on["rt"]
    eps_prev = np.zeros(3)
    for r in rt.rounds:
        cf = np.asarray(r.metrics["clip_frac"])
        assert ((cf >= 0) & (cf <= 1)).all()
        assert r.epsilon is not None and r.epsilon.shape == (3,)
        assert (r.epsilon > eps_prev).all()       # cumulative composition
        eps_prev = r.epsilon
    # the series terminates at exactly the real accountant's epsilons
    report = on["st"].privacy_report()
    np.testing.assert_allclose(
        rt.rounds[-1].epsilon, [r["epsilon"] for r in report], rtol=1e-9)


def test_observed_dp_telemetry_engine_parity(tiny_setup):
    rc = _run(tiny_setup, "fl", "compiled", observed=True, privacy=DP)["rt"]
    rs = _run(tiny_setup, "fl", "stepwise", observed=True, privacy=DP)["rt"]
    for a, b in zip(rc.rounds, rs.rounds):
        for k in a.metrics:
            np.testing.assert_allclose(a.metrics[k], b.metrics[k],
                                       atol=1e-4, err_msg=k)
        np.testing.assert_allclose(a.epsilon, b.epsilon, rtol=1e-9)


# ---------------------------------------------------------------------------
# placement: telemetry rides the hosp mesh, phantom hospitals un-padded
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["fl", "sflv3_ac"])
def test_observed_sharded_run(method, tiny_setup):
    """shard=True: metric stacks ride the "hosp" mesh next to the losses;
    the reduced telemetry is identical to the unsharded run and params
    match the unsharded observed run (exercised for real on the
    8-virtual-device CI job; a no-op mesh on one device)."""
    plain = _run(tiny_setup, method, "compiled", observed=True)
    sharded = _run(tiny_setup, method, "compiled", observed=True,
                   shard=True)
    assert sharded["dispatches"] == 1
    for a, b in zip(plain["leaves"], sharded["leaves"]):
        np.testing.assert_allclose(a, b, atol=1e-5)
    for ra, rb in zip(plain["rt"].rounds, sharded["rt"].rounds):
        assert set(ra.metrics) == set(rb.metrics)
        for k in ra.metrics:
            assert np.asarray(rb.metrics[k]).shape == (3,)   # un-padded
            np.testing.assert_allclose(ra.metrics[k], rb.metrics[k],
                                       atol=1e-4, err_msg=k)


# ---------------------------------------------------------------------------
# spec plumbing: make_strategy(observe=), run(observe=), as_telemetry
# ---------------------------------------------------------------------------

def test_as_telemetry_normalization():
    assert as_telemetry(None) is None
    assert as_telemetry(False) is None
    assert as_telemetry(True) == Telemetry()
    t = Telemetry(cut_stats=False)
    assert as_telemetry(t) is t
    off = Telemetry(loss=False, norms=False, update_cosine=False,
                    cut_stats=False, clip_fraction=False, epsilon=False)
    assert as_telemetry(off) is None              # all-off spec == off
    with pytest.raises(TypeError):
        as_telemetry("yes")


def test_run_observe_override(tiny_setup):
    """run(observe=) overrides the constructor spec per run: False
    disables, a Telemetry enables, None inherits."""
    clients, adapter = tiny_setup
    data = [c.train for c in clients]
    st = make_strategy("fl", adapter, lambda: O.adam(1e-3), 3,
                       observe=Telemetry())
    state = st.setup(jax.random.key(0))
    state, logs = st.run(state, data, np.random.default_rng(0), 4, 1,
                         observe=False)
    assert st.last_run_telemetry is None
    assert logs[0].telemetry is None
    state, logs = st.run(state, data, np.random.default_rng(0), 4, 1)
    assert st.last_run_telemetry is not None      # inherits constructor


def test_telemetry_flag_subsets(tiny_setup):
    """Disabled taps are absent — the step metric key set is static per
    spec, so a norms-off run never computes norms."""
    clients, adapter = tiny_setup
    st = make_strategy("sl_am", adapter, lambda: O.adam(1e-3), 3)
    state = st.setup(jax.random.key(0))
    spec = Telemetry(norms=False, cut_stats=False)
    st.run(state, [c.train for c in clients], np.random.default_rng(0),
           4, 1, observe=spec)
    r = st.last_run_telemetry.rounds[0]
    assert set(r.metrics) == {"loss"}
    assert spec.step_keys(dp=False, cut=True) == ()


def test_step_keys_static_sets():
    t = Telemetry()
    assert t.step_keys(dp=False, cut=False) == ("grad_norm", "update_norm")
    assert t.step_keys(dp=True, cut=True) == (
        "grad_norm", "update_norm", "cut_mean", "cut_std", "cut_absmax",
        "clip_frac")


# ---------------------------------------------------------------------------
# trace.py: span tree, synthetic round slices, wire lanes, merged JSON
# ---------------------------------------------------------------------------

def test_tracer_span_tree():
    from repro.obs.trace import Tracer
    tr = Tracer()
    with tr.span("run", strategy="fl"):
        with tr.span("pack"):
            pass
        with tr.span("dispatch"):
            pass
    names = [e["name"] for e in tr.events]
    assert names == ["pack", "dispatch", "run"]   # children close first
    run = tr.find("run")
    disp = tr.find("dispatch")
    assert run["args"]["strategy"] == "fl" and run["args"]["depth"] == 0
    assert disp["args"]["depth"] == 1
    # children nest inside the parent span's interval
    assert run["ts"] <= disp["ts"]
    assert disp["ts"] + disp["dur"] <= run["ts"] + run["dur"] + 1.0
    assert any(e["ph"] == "M" for e in tr.trace_events())


def test_strategy_records_spans(tiny_setup):
    from repro.obs.trace import Tracer
    clients, adapter = tiny_setup
    st = make_strategy("fl", adapter, lambda: O.adam(1e-3), 3,
                       observe=Telemetry())
    tr = st.attach_tracer(Tracer())
    state = st.setup(jax.random.key(0))
    st.run(state, [c.train for c in clients], np.random.default_rng(0),
           4, 2)
    assert tr.find("run") is not None
    assert tr.find("pack") is not None
    assert tr.find("dispatch") is not None


def test_round_events_synthetic_slices(tiny_setup):
    from repro.obs.trace import round_events
    rt = _run(tiny_setup, "fl", "compiled", observed=True,
              privacy=DP)["rt"]
    span = {"ts": 100.0, "dur": 50.0}
    evs = round_events(rt, span)
    slices = [e for e in evs if e["ph"] == "X"]
    counters = [e for e in evs if e["ph"] == "C"]
    assert len(slices) == EPOCHS and len(counters) == EPOCHS
    for i, e in enumerate(slices):
        assert e["args"]["synthetic"] is True
        assert e["ts"] == pytest.approx(100.0 + i * 25.0)
        assert e["dur"] == pytest.approx(25.0)
        assert "loss" in e["args"]
    assert set(counters[0]["args"]) == {"hospital0", "hospital1",
                                        "hospital2"}
    assert round_events(type(rt)("fl", 3, []), span) == []


def test_wire_events_and_merge(tmp_path):
    from repro.obs.trace import (PID_WIRE, merge_events, wire_events,
                                 write_chrome_trace)
    from repro.wire.simulator import simulate
    clients = make_cxr_clients(seed=0, train_per_client=[8, 8],
                               val_per_client=4, test_per_client=4,
                               image_size=8, n_clients=2)
    cfg = DenseNetConfig(growth=2, blocks=(1, 1), stem_ch=4, cut_layer=1)
    adapter = cnn_adapter(build_densenet(cfg))
    sim = simulate("sl_ac", adapter, {k: v[:1] for k, v in
                                      clients[0].train.items()},
                   [8, 8], [4, 4], 4)
    evs = wire_events(sim)
    xs = [e for e in evs if e["ph"] == "X"]
    assert xs and all(e["pid"] == PID_WIRE for e in xs)
    assert sum(e["args"]["bytes"] for e in xs) == int(sim.bytes_on_wire)
    merged = merge_events(evs, pid_offset=10)
    assert all(e["pid"] == PID_WIRE + 10 for e in merged
               if e["ph"] == "X")
    path = write_chrome_trace(merged, tmp_path / "trace.json")
    with open(path) as f:
        doc = json.load(f)
    assert isinstance(doc["traceEvents"], list)
    assert len(doc["traceEvents"]) == len(merged)


# ---------------------------------------------------------------------------
# profile.py + report.py
# ---------------------------------------------------------------------------

def test_cost_summary_and_hlo(tiny_setup):
    from repro.obs.profile import cost_summary, hlo_cost
    on = _run(tiny_setup, "fl", "compiled", observed=True)
    cost = hlo_cost(on["st"])
    assert cost is not None
    assert cost["compile_seconds"] > 0
    assert cost["flops"] > 0
    summary = cost_summary(on["st"], wall_seconds=2.0, total_steps=22)
    assert summary["strategy"] == "fl"
    assert summary["dispatches"] == 1
    assert summary["steps_per_s"] == pytest.approx(11.0)


def test_hlo_cost_requires_a_compiled_run(tiny_setup):
    from repro.obs.profile import hlo_cost
    clients, adapter = tiny_setup
    st = make_strategy("fl", adapter, lambda: O.adam(1e-3), 3)
    assert hlo_cost(st) is None                  # nothing dispatched yet


def test_runlog_and_report(tiny_setup, tmp_path):
    from repro.obs.report import (render_markdown, write_report,
                                  write_runlog)
    on = _run(tiny_setup, "fl", "compiled", observed=True)
    rt = on["rt"]
    path = write_runlog(tmp_path, "fl", telemetry=rt,
                        cost={"dispatches": 1}, extra={"note": "test"})
    with open(path) as f:
        doc = json.load(f)
    assert doc["telemetry"]["strategy"] == "fl"
    assert len(doc["telemetry"]["rounds"]) == EPOCHS
    assert doc["cost"]["dispatches"] == 1 and doc["note"] == "test"
    md = render_markdown(rt, cost={"dispatches": 1})
    assert "| round |" in md and "loss" in md
    rpath = write_report(tmp_path, "fl", rt)
    assert "| round |" in open(rpath).read()
    # table renders one row per round with finite scalars
    lines = rt.table().splitlines()
    assert len(lines) == 2 + EPOCHS


def test_jax_profile_context(tmp_path):
    from repro.obs.profile import jax_profile
    with jax_profile(tmp_path / "jaxtrace"):
        jax.block_until_ready(jax.numpy.ones((4,)) * 2)
    # best-effort: no crash whether or not the profiler plugin exists
