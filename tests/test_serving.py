"""Serving engine: prefill+decode consistency, sliding-window ring cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import ModelConfig, TransformerLM
from repro.serving.engine import greedy_generate, make_decode_step, \
    make_prefill_step


def _model(**kw):
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=3, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                      cut_layer=1, remat=False, compute_dtype=jnp.float32,
                      **kw)
    return TransformerLM.build(cfg), cfg


@pytest.mark.slow
def test_prefill_then_decode_matches_full():
    model, cfg = _model()
    params = model.init_params(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 9), 0, 97)
    full, _, _ = model.apply(params, toks)

    prefill = make_prefill_step(model, max_len=16, cache_dtype=jnp.float32)
    logits8, cache = prefill(params, {"tokens": toks[:, :8]})
    np.testing.assert_allclose(np.asarray(logits8),
                               np.asarray(full[:, 7]), rtol=2e-5, atol=2e-5)
    decode = make_decode_step(model)
    lg, cache = decode(params, cache, toks[:, 8:9],
                       jnp.full((2, 1), 8, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, 8]),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.slow
def test_sliding_window_ring_cache_matches_full_attention_window():
    """Decode through a window-sized ring cache == windowed attention."""
    model, cfg = _model(sliding_window=4)
    params = model.init_params(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 12), 0, 97)
    full, _, _ = model.apply(params, toks)       # masked sliding attention

    # decode token-by-token with a 4-slot ring cache
    cache = model.cache_init(1, 64, dtype=jnp.float32)   # clamped to window
    kv = [l for l in jax.tree.leaves(cache["front"]) if l.ndim == 5]
    assert kv and all(l.shape[2] == 4 for l in kv)       # ring size == window
    decode = make_decode_step(model)
    outs = []
    for t in range(12):
        lg, cache = decode(params, cache, toks[:, t:t + 1],
                           jnp.full((1, 1), t, jnp.int32))
        outs.append(lg[:, None])
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_bulk_prefill_into_ring_cache_then_decode():
    model, cfg = _model(sliding_window=4)
    params = model.init_params(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (1, 9), 0, 97)
    full, _, _ = model.apply(params, toks)
    prefill = make_prefill_step(model, max_len=8, cache_dtype=jnp.float32)
    logits, cache = prefill(params, {"tokens": toks[:, :8]})
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, 7]),
                               rtol=2e-4, atol=2e-4)
    decode = make_decode_step(model)
    lg, _ = decode(params, cache, toks[:, 8:9],
                   jnp.full((1, 1), 8, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, 8]),
                               rtol=2e-4, atol=2e-4)


def test_greedy_generate_shapes():
    model, cfg = _model()
    params = model.init_params(jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1), (2, 5), 0, 97)
    out = greedy_generate(model, params, prompt, max_new=4, max_len=16)
    assert out.shape == (2, 4)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < 97).all()
    # deterministic
    out2 = greedy_generate(model, params, prompt, max_new=4, max_len=16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
