"""repro.core.aggregate: pluggable aggregation rules.

Covers the registry, the bit-identity of the default weighted mean with
the pre-refactor expressions, the zero-total-weight guards (S1: an empty
Poisson round must keep the previous globals, never NaN them), and the
semantics of the robust/staleness/hierarchical aggregators.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregate as AGG
from repro.core.partition import stack_trees


def _trees(vals):
    return [{"w": jnp.asarray(v, jnp.float32),
             "b": jnp.asarray([v[0] * 2.0], jnp.float32)} for v in vals]


def _flat(tree):
    return np.concatenate([np.asarray(l, np.float64).ravel()
                           for l in jax.tree.leaves(tree)])


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_lookup_and_default():
    assert isinstance(AGG.make_aggregator(None), AGG.WeightedMean)
    assert isinstance(AGG.make_aggregator("weighted_mean"), AGG.WeightedMean)
    assert isinstance(AGG.make_aggregator("trimmed_mean"), AGG.TrimmedMean)
    agg = AGG.TrimmedMean(trim=0.2)
    assert AGG.make_aggregator(agg) is agg
    with pytest.raises(ValueError, match="weighted_mean"):
        AGG.make_aggregator("nope")
    with pytest.raises(TypeError):
        AGG.make_aggregator(3.0)


def test_registry_register_custom():
    class Custom(AGG.Aggregator):
        name = "custom_test"

        def aggregate(self, stacked, weights, prev, staleness=None,
                      gids=None):
            return AGG.weighted_mean_guarded(stacked, weights, prev)

    AGG.register("custom_test", Custom)
    try:
        assert isinstance(AGG.make_aggregator("custom_test"), Custom)
    finally:
        del AGG.AGGREGATORS["custom_test"]


# ---------------------------------------------------------------------------
# weighted mean: bit-identity with the naive expression + zero guards (S1)
# ---------------------------------------------------------------------------

def test_tree_weighted_mean_matches_naive():
    trees = _trees([[1.0, 2.0], [3.0, 4.0], [5.0, 6.0]])
    weights = [17, 12, 9]
    got = AGG.tree_weighted_mean(trees, weights)
    total = sum(weights)
    want = jax.tree.map(
        lambda *xs: sum(w * x for w, x in zip(weights, xs)) / total, *trees)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_tree_weighted_mean_zero_total_keeps_prev():
    trees = _trees([[1.0, 2.0], [3.0, 4.0]])
    prev = {"w": jnp.asarray([9.0, 9.0], jnp.float32),
            "b": jnp.asarray([9.0], jnp.float32)}
    got = AGG.tree_weighted_mean(trees, [0, 0], prev=prev)
    assert np.array_equal(_flat(got), _flat(prev))
    # without prev: falls back to the unweighted mean, still finite
    got2 = AGG.tree_weighted_mean(trees, [0, 0])
    assert np.all(np.isfinite(_flat(got2)))


def test_stacked_weighted_mean_zero_total_keeps_prev():
    trees = _trees([[1.0, 2.0], [3.0, 4.0]])
    stacked = stack_trees(trees)
    prev = trees[0]
    got = AGG.stacked_weighted_mean(stacked, np.zeros(2, np.float32), prev)
    assert np.array_equal(_flat(got), _flat(prev))
    # positive weights: matches the eager tree path exactly
    w = np.asarray([3.0, 1.0], np.float32)
    a = AGG.stacked_weighted_mean(stacked, w)
    b = AGG.tree_weighted_mean(trees, list(w))
    np.testing.assert_allclose(_flat(a), _flat(b), atol=1e-6)


def test_weighted_mean_guarded_traced_zero_guard():
    trees = _trees([[1.0, 2.0], [3.0, 4.0]])
    stacked = stack_trees(trees)
    prev = trees[1]

    @jax.jit
    def agg(s, w, p):
        return AGG.weighted_mean_guarded(s, w, p)

    got = agg(stacked, jnp.zeros(2), prev)
    assert np.array_equal(_flat(got), _flat(prev))
    got2 = agg(stacked, jnp.asarray([1.0, 3.0]), prev)
    assert np.all(np.isfinite(_flat(got2)))


# ---------------------------------------------------------------------------
# robust rules
# ---------------------------------------------------------------------------

def test_trimmed_mean_drops_outlier():
    # 5 honest rows around 1.0 plus one byzantine row at 1e6
    vals = [[1.0, 1.0], [1.1, 0.9], [0.9, 1.1], [1.0, 1.2], [1.2, 0.8],
            [1e6, -1e6]]
    stacked = stack_trees(_trees(vals))
    w = jnp.ones(6)
    agg = AGG.TrimmedMean(trim=0.2)   # floor(0.2 * 6) = 1 from each end
    out = agg.host(stacked, w, prev=_trees(vals)[0])
    flat = _flat(out)
    assert np.all(np.abs(flat) < 10.0), flat


def test_trimmed_mean_ignores_zero_weight_rows():
    vals = [[1.0, 1.0], [3.0, 3.0], [1e9, 1e9]]
    stacked = stack_trees(_trees(vals))
    w = jnp.asarray([1.0, 1.0, 0.0])   # byzantine row wasn't sampled
    agg = AGG.TrimmedMean(trim=0.0)
    out = agg.host(stacked, w, prev=_trees(vals)[0])
    np.testing.assert_allclose(np.asarray(out["w"]), [2.0, 2.0], atol=1e-6)


def test_coordinate_median():
    vals = [[1.0, 10.0], [2.0, 20.0], [100.0, 30.0]]
    stacked = stack_trees(_trees(vals))
    agg = AGG.CoordinateMedian()
    out = agg.host(stacked, jnp.ones(3), prev=_trees(vals)[0])
    np.testing.assert_allclose(np.asarray(out["w"]), [2.0, 20.0], atol=1e-6)
    # even count: midpoint of the two central order statistics
    out2 = agg.host(stack_trees(_trees(vals[:2])), jnp.ones(2),
                    prev=_trees(vals)[0])
    np.testing.assert_allclose(np.asarray(out2["w"]), [1.5, 15.0],
                               atol=1e-6)


def test_coordinate_median_zero_total_keeps_prev():
    vals = [[1.0, 2.0], [3.0, 4.0]]
    stacked = stack_trees(_trees(vals))
    prev = _trees(vals)[1]
    out = AGG.CoordinateMedian().host(stacked, jnp.zeros(2), prev=prev)
    assert np.array_equal(_flat(out), _flat(prev))


def test_staleness_discounted():
    vals = [[0.0, 0.0], [4.0, 4.0]]
    stacked = stack_trees(_trees(vals))
    prev = _trees(vals)[0]
    agg = AGG.StalenessDiscounted(decay=0.5)
    # row 1 is 2 rounds stale: weight 1 * 0.5^2 = 0.25 against 1.0
    out = agg.aggregate(stacked, jnp.ones(2), prev,
                        staleness=jnp.asarray([0.0, 2.0]))
    np.testing.assert_allclose(np.asarray(out["w"]),
                               [4.0 * 0.25 / 1.25] * 2, atol=1e-6)
    # no staleness given: plain weighted mean
    out2 = agg.host(stacked, jnp.ones(2), prev)
    np.testing.assert_allclose(np.asarray(out2["w"]), [2.0, 2.0],
                               atol=1e-6)


def test_hierarchical_two_tier():
    # regions (0, 0, 1): tier-1 weighted means inside each region, tier-2
    # UNWEIGHTED mean over the two non-empty regions
    vals = [[0.0, 0.0], [2.0, 2.0], [10.0, 10.0]]
    stacked = stack_trees(_trees(vals))
    prev = _trees(vals)[0]
    agg = AGG.Hierarchical(regions=(0, 0, 1))
    out = agg.host(stacked, jnp.asarray([1.0, 3.0, 1.0]), prev)
    # region 0: (0*1 + 2*3)/4 = 1.5 ; region 1: 10 ; tier2: 5.75
    np.testing.assert_allclose(np.asarray(out["w"]), [5.75, 5.75],
                               atol=1e-6)
    # a region whose hospitals were all unsampled drops out of tier 2
    out2 = agg.host(stacked, jnp.asarray([1.0, 3.0, 0.0]), prev)
    np.testing.assert_allclose(np.asarray(out2["w"]), [1.5, 1.5],
                               atol=1e-6)


# ---------------------------------------------------------------------------
# the strategies' aggregation endpoints (S1 regression: empty FL round)
# ---------------------------------------------------------------------------

def test_fl_run_with_robust_aggregator():
    from repro import optim as O
    from repro.core.partition import cnn_adapter
    from repro.core.strategies import make_strategy
    from repro.data.synthetic import make_cxr_clients
    from repro.models.cnn import DenseNetConfig, build_densenet

    clients = make_cxr_clients(seed=0, train_per_client=12,
                               val_per_client=4, test_per_client=4,
                               image_size=16, n_clients=3)
    adapter = cnn_adapter(build_densenet(
        DenseNetConfig(growth=2, blocks=(1, 1), stem_ch=4, cut_layer=1)))
    for spec in ["trimmed_mean", "coordinate_median"]:
        st = make_strategy("fl", adapter, lambda: O.adam(1e-3), 3,
                           aggregator=spec)
        state = st.setup(jax.random.key(0))
        state, logs = st.run(state, [c.train for c in clients],
                             np.random.default_rng(0), 4, 2)
        assert st._dispatches == 1          # still ONE fused dispatch
        flat = _flat(st.params_for_eval(state, 0))
        assert np.all(np.isfinite(flat))


def test_aggregator_rejected_outside_fl():
    from repro import optim as O
    from repro.core.partition import cnn_adapter
    from repro.core.strategies import make_strategy
    from repro.models.cnn import DenseNetConfig, build_densenet

    adapter = cnn_adapter(build_densenet(
        DenseNetConfig(growth=2, blocks=(1, 1), stem_ch=4, cut_layer=1)))
    with pytest.raises(ValueError, match="fl only"):
        make_strategy("sl_ac", adapter, lambda: O.adam(1e-3), 3,
                      aggregator="trimmed_mean")
    with pytest.raises(ValueError, match="secagg"):
        from repro.privacy import PrivacyConfig
        make_strategy("fl", adapter, lambda: O.adam(1e-3), 3,
                      privacy=PrivacyConfig(secagg=True),
                      aggregator="trimmed_mean")
