"""Per-architecture smoke tests (assignment deliverable f).

Each assigned architecture's REDUCED same-family variant runs one forward
and one train step on CPU; output shapes and finiteness are asserted.
The FULL configs are exercised only via the dry-run (no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim as O
from repro.configs.registry import REGISTRY, ARCH_IDS
from repro.models.transformer import TransformerLM


def _smoke_batch(cfg, key, batch=2, seq=32):
    ks = jax.random.split(key, 2)
    b = {"tokens": jax.random.randint(ks[0], (batch, seq), 0,
                                      cfg.vocab_size)}
    if cfg.frontend is not None:
        b["frontend_emb"] = jax.random.normal(
            ks[1], (batch, cfg.frontend_tokens, cfg.frontend_dim),
            jnp.float32)
    return b


# the biggest smoke configs dominate tier-1 wall-clock; the slow job
# still runs them on every push
_HEAVY_SMOKE = {"zamba2-7b", "kimi-k2-1t-a32b", "musicgen-medium",
                "minicpm-2b"}


@pytest.mark.parametrize("arch_id", [
    pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY_SMOKE else a
    for a in ARCH_IDS])
def test_smoke_forward(arch_id):
    cfg = REGISTRY[arch_id].smoke
    model = TransformerLM.build(cfg)
    params = model.init_params(jax.random.key(0))
    batch = _smoke_batch(cfg, jax.random.key(1))
    logits, _, aux = model.apply(params, batch["tokens"],
                                 frontend_emb=batch.get("frontend_emb"))
    b, s = batch["tokens"].shape
    extra = cfg.frontend_tokens if cfg.frontend is not None else 0
    assert logits.shape == (b, s + extra, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch_id
    assert bool(jnp.isfinite(aux)), arch_id


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id):
    cfg = REGISTRY[arch_id].smoke
    model = TransformerLM.build(cfg)
    params = model.init_params(jax.random.key(0))
    batch = _smoke_batch(cfg, jax.random.key(1))
    opt = O.adam(1e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(p, s, b):
        loss, grads = jax.value_and_grad(model.loss)(p, b)
        upd, s = opt.update(grads, s, p)
        return O.apply_updates(p, upd), s, loss

    p1, opt_state, l1 = step(params, opt_state, batch)
    p2, opt_state, l2 = step(p1, opt_state, batch)
    assert np.isfinite(float(l1)) and np.isfinite(float(l2)), arch_id
    # params actually moved
    delta = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
    assert delta > 0, arch_id


@pytest.mark.slow
@pytest.mark.parametrize("arch_id", ["mamba2-130m", "zamba2-7b",
                                     "smollm-135m"])
def test_smoke_decode(arch_id):
    """Decode-with-cache matches full forward on the decode-capable families."""
    cfg = REGISTRY[arch_id].smoke
    cfg = type(cfg)(**{**cfg.__dict__, "compute_dtype": jnp.float32})
    model = TransformerLM.build(cfg)
    params = model.init_params(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    full, _, _ = model.apply(params, toks)
    cache = model.cache_init(2, 16, dtype=jnp.float32)
    outs = []
    for t in range(8):
        pos = jnp.full((2, 1), t, jnp.int32)
        o, cache, _ = model.apply(params, toks[:, t:t + 1], positions=pos,
                                  cache=cache)
        outs.append(o)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               atol=2e-4, rtol=2e-3)
