"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.act_compress.ops import (quantize, dequantize,
                                            compress_boundary)
from repro.kernels.act_compress.ref import quantize_ref


@pytest.mark.parametrize("b,h,kv,s,d", [
    (1, 2, 1, 128, 64), (2, 4, 2, 256, 64),
    pytest.param(1, 8, 8, 128, 128, marks=pytest.mark.slow),
    pytest.param(1, 4, 1, 512, 32, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, h, kv, s, d, causal, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, kv, s, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, kv, s, d)).astype(dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=128,
                                 block_k=128)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("b,l,h,p,g,n,q", [
    (1, 64, 2, 16, 1, 16, 16),
    pytest.param(2, 128, 4, 32, 2, 32, 32, marks=pytest.mark.slow),
    pytest.param(1, 256, 8, 64, 1, 128, 128, marks=pytest.mark.slow),
    (1, 96, 3, 16, 1, 64, 32),
])
def test_ssd_kernel(b, l, h, p, g, n, q):
    ks = jax.random.split(jax.random.key(0), 4)
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, l, g, n)) * 0.5
    C = jax.random.normal(jax.random.key(7), (b, l, g, n)) * 0.5
    y, fs = ssd(x, dt, A, B, C, q)
    yr, fsr = ssd_ref(x, dt, A, B, C, q)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fsr),
                               atol=3e-4, rtol=3e-4)


def test_ssd_matches_naive_recurrence():
    """SSD chunked == the literal per-token recurrence (the defining law)."""
    b, l, h, p, g, n, q = 1, 32, 2, 8, 1, 8, 8
    ks = jax.random.split(jax.random.key(3), 4)
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, l, g, n)) * 0.5
    C = jax.random.normal(jax.random.key(9), (b, l, g, n)) * 0.5
    y, fs = ssd(x, dt, A, B, C, q)

    S = np.zeros((b, h, p, n), np.float32)
    ys = np.zeros((b, l, h, p), np.float32)
    xn, dtn, An = map(np.asarray, (x, dt, A))
    Bn = np.repeat(np.asarray(B), h // g, axis=2)
    Cn = np.repeat(np.asarray(C), h // g, axis=2)
    for t in range(l):
        da = np.exp(-dtn[:, t] * An)                      # (b,h)
        xb = xn[:, t] * dtn[:, t][..., None]              # (b,h,p)
        S = S * da[:, :, None, None] + np.einsum(
            "bhp,bhn->bhpn", xb, Bn[:, t])
        ys[:, t] = np.einsum("bhn,bhpn->bhp", Cn[:, t], S)
    np.testing.assert_allclose(np.asarray(y), ys, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(fs), S, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("shape", [(64, 128), (8, 32, 64), (250, 512),
                                   (7, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_matches_ref(shape, dtype):
    x = (jax.random.normal(jax.random.key(0), shape) * 3).astype(dtype)
    q, s = quantize(x)
    qr, sr = quantize_ref(np.asarray(x, np.float32).reshape(-1, shape[-1]))
    dq = np.abs(np.asarray(q).reshape(-1, shape[-1]).astype(np.int32)
                - np.asarray(qr).astype(np.int32))
    if dtype == jnp.float32:
        assert dq.max() == 0
    else:
        assert dq.max() <= 1      # bf16 rounding ties may flip one level
    np.testing.assert_allclose(np.asarray(s).reshape(-1, 1),
                               np.asarray(sr), rtol=1e-2)


def test_roundtrip_error_bound():
    x = jax.random.normal(jax.random.key(1), (128, 256)) * 5
    rt = dequantize(*quantize(x), dtype=jnp.float32)
    amax = np.abs(np.asarray(x)).max(axis=1, keepdims=True)
    assert (np.abs(np.asarray(rt) - np.asarray(x)) <=
            amax / 127.0 + 1e-6).all()


def test_compress_boundary_gradient_is_identity():
    x = jax.random.normal(jax.random.key(2), (16, 64))
    g = jax.grad(lambda xx: (compress_boundary(xx) * xx).sum())(x)
    # STE: d/dx [stopgrad-ish roundtrip(x) * x] = roundtrip(x) + x
    expect = compress_boundary(x) + x
    np.testing.assert_allclose(np.asarray(g), np.asarray(expect), atol=1e-5)


# ---------------------------------------------------------------------------
# cut_fuse: fused roundtrip(+noise) vs the unfused composition — BIT equality
# ---------------------------------------------------------------------------
# The fused kernel is gated on exact equality against the unfused path
# WITHIN each execution mode (eager and jit separately).  Eager-vs-jit may
# differ by 1 ulp for std != 1 because XLA constant-merges the normal()
# sqrt(2) with the std scale only under jit — identically for both paths,
# so the fused/unfused comparison stays exact in either mode.

from repro.kernels.cut_fuse.ops import (cut_noise_roundtrip, fused_roundtrip,
                                        roundtrip_boundary)
from repro.kernels.cut_fuse.ref import noise_roundtrip_ref
from repro.privacy.dpsgd import _leaf_noise, cut_noise_boundary
from repro.wire.codec import Int8Codec

CUT_SHAPES = [(8, 32), (7, 16), (6, 4, 4, 8)]      # (7, 16) pads the grid


def _bits(x):
    a = np.asarray(x)
    return a.view(np.uint8 if a.dtype.itemsize == 1 else
                  {2: np.uint16, 4: np.uint32}[a.dtype.itemsize])


@pytest.mark.parametrize("shape", CUT_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cut_fuse_roundtrip_bit_equal(shape, dtype):
    x = (jax.random.normal(jax.random.key(0), shape) * 3).astype(dtype)
    for f in (lambda a: (fused_roundtrip(a), compress_boundary(a)),
              jax.jit(lambda a: (fused_roundtrip(a), compress_boundary(a)))):
        fused, unfused = f(x)
        assert fused.dtype == x.dtype
        np.testing.assert_array_equal(_bits(fused), _bits(unfused))


@pytest.mark.parametrize("shape", CUT_SHAPES)
@pytest.mark.parametrize("std", [0.37, 1.0])
@pytest.mark.parametrize("masked", [False, True])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cut_fuse_noise_bit_equal(shape, std, masked, dtype):
    codec = Int8Codec()
    x = (jax.random.normal(jax.random.key(1), shape) * 3).astype(dtype)
    w = None
    if masked:       # remainder batch: trailing rows are padding
        w = (jnp.arange(shape[0]) < shape[0] - 2).astype(jnp.float32)
    unfused = cut_noise_boundary(lambda t: jax.tree.map(codec.roundtrip, t),
                                 std, codec=None)
    fused = cut_noise_boundary(None, std, codec=codec)
    key = jax.random.key(5)

    def u(t, k):
        return unfused(t, k, w)

    def f(t, k):
        return fused(t, k, w)

    for a_fn, b_fn in ((u, f), (jax.jit(u), jax.jit(f))):
        a, b = a_fn(x, key), b_fn(x, key)
        assert b.dtype == x.dtype
        np.testing.assert_array_equal(_bits(a), _bits(b))
    if masked:       # padded rows ship the CLEAN roundtrip, no noise
        noised = f(x, key)
        clean = fused_roundtrip(x)
        np.testing.assert_array_equal(_bits(noised[-2:]), _bits(clean[-2:]))


@pytest.mark.parametrize("shape", [(8, 32), (7, 16)])
def test_cut_fuse_matches_ref_oracle(shape):
    """Fused stays within float-rounding of the pure-eager oracle.

    Bit equality is gated against the unfused IN-GRAPH composition above;
    the eager ``ref`` oracle may differ by 1 ulp where jit strength-reduces
    the quantizer division, so this gate is closeness, not bits."""
    std = 0.37
    x = jax.random.normal(jax.random.key(2), shape) * 3
    lk = jax.random.fold_in(jax.random.key(6), jnp.uint32(0))
    ks = jax.vmap(lambda i: jax.random.fold_in(lk, i))(
        jnp.arange(shape[0], dtype=jnp.uint32))
    z0 = jax.vmap(lambda k: jax.random.normal(k, shape[1:], jnp.float32))(ks)
    zz = _leaf_noise(x, lk, std)           # pre-scaled, the fused input
    np.testing.assert_array_equal(np.asarray(zz), np.asarray(std * z0))
    fused = cut_noise_roundtrip(x, zz)
    ref = noise_roundtrip_ref(x, z0, std)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               atol=1e-6, rtol=0)


def test_cut_fuse_ste_gradients():
    x = jax.random.normal(jax.random.key(3), (8, 32))
    z = jax.random.normal(jax.random.key(4), (8, 32))
    w = jnp.ones((8,), jnp.float32)
    g = jax.grad(lambda a: (cut_noise_roundtrip(a, z, w) * a).sum())(x)
    expect = cut_noise_roundtrip(x, z, w) + x
    np.testing.assert_allclose(np.asarray(g), np.asarray(expect), atol=1e-5)
    gz = jax.grad(lambda a: cut_noise_roundtrip(x, a, w).sum())(z)
    assert not np.asarray(gz).any()        # PRNG bits are not differentiated
    g2 = jax.grad(lambda a: (roundtrip_boundary(a) * a).sum())(x)
    np.testing.assert_allclose(np.asarray(g2),
                               np.asarray(roundtrip_boundary(x) + x),
                               atol=1e-5)
