"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.act_compress.ops import (quantize, dequantize,
                                            compress_boundary)
from repro.kernels.act_compress.ref import quantize_ref, roundtrip_ref


@pytest.mark.parametrize("b,h,kv,s,d", [
    (1, 2, 1, 128, 64), (2, 4, 2, 256, 64),
    pytest.param(1, 8, 8, 128, 128, marks=pytest.mark.slow),
    pytest.param(1, 4, 1, 512, 32, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, h, kv, s, d, causal, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, h, s, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, kv, s, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, kv, s, d)).astype(dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, block_q=128,
                                 block_k=128)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("b,l,h,p,g,n,q", [
    (1, 64, 2, 16, 1, 16, 16),
    pytest.param(2, 128, 4, 32, 2, 32, 32, marks=pytest.mark.slow),
    pytest.param(1, 256, 8, 64, 1, 128, 128, marks=pytest.mark.slow),
    (1, 96, 3, 16, 1, 64, 32),
])
def test_ssd_kernel(b, l, h, p, g, n, q):
    ks = jax.random.split(jax.random.key(0), 4)
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, l, g, n)) * 0.5
    C = jax.random.normal(jax.random.key(7), (b, l, g, n)) * 0.5
    y, fs = ssd(x, dt, A, B, C, q)
    yr, fsr = ssd_ref(x, dt, A, B, C, q)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fsr),
                               atol=3e-4, rtol=3e-4)


def test_ssd_matches_naive_recurrence():
    """SSD chunked == the literal per-token recurrence (the defining law)."""
    b, l, h, p, g, n, q = 1, 32, 2, 8, 1, 8, 8
    ks = jax.random.split(jax.random.key(3), 4)
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, l, g, n)) * 0.5
    C = jax.random.normal(jax.random.key(9), (b, l, g, n)) * 0.5
    y, fs = ssd(x, dt, A, B, C, q)

    S = np.zeros((b, h, p, n), np.float32)
    ys = np.zeros((b, l, h, p), np.float32)
    xn, dtn, An = map(np.asarray, (x, dt, A))
    Bn = np.repeat(np.asarray(B), h // g, axis=2)
    Cn = np.repeat(np.asarray(C), h // g, axis=2)
    for t in range(l):
        da = np.exp(-dtn[:, t] * An)                      # (b,h)
        xb = xn[:, t] * dtn[:, t][..., None]              # (b,h,p)
        S = S * da[:, :, None, None] + np.einsum(
            "bhp,bhn->bhpn", xb, Bn[:, t])
        ys[:, t] = np.einsum("bhn,bhpn->bhp", Cn[:, t], S)
    np.testing.assert_allclose(np.asarray(y), ys, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(fs), S, atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("shape", [(64, 128), (8, 32, 64), (250, 512),
                                   (7, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_matches_ref(shape, dtype):
    x = (jax.random.normal(jax.random.key(0), shape) * 3).astype(dtype)
    q, s = quantize(x)
    qr, sr = quantize_ref(np.asarray(x, np.float32).reshape(-1, shape[-1]))
    dq = np.abs(np.asarray(q).reshape(-1, shape[-1]).astype(np.int32)
                - np.asarray(qr).astype(np.int32))
    if dtype == jnp.float32:
        assert dq.max() == 0
    else:
        assert dq.max() <= 1      # bf16 rounding ties may flip one level
    np.testing.assert_allclose(np.asarray(s).reshape(-1, 1),
                               np.asarray(sr), rtol=1e-2)


def test_roundtrip_error_bound():
    x = jax.random.normal(jax.random.key(1), (128, 256)) * 5
    rt = dequantize(*quantize(x), dtype=jnp.float32)
    amax = np.abs(np.asarray(x)).max(axis=1, keepdims=True)
    assert (np.abs(np.asarray(rt) - np.asarray(x)) <=
            amax / 127.0 + 1e-6).all()


def test_compress_boundary_gradient_is_identity():
    x = jax.random.normal(jax.random.key(2), (16, 64))
    g = jax.grad(lambda xx: (compress_boundary(xx) * xx).sum())(x)
    # STE: d/dx [stopgrad-ish roundtrip(x) * x] = roundtrip(x) + x
    expect = compress_boundary(x) + x
    np.testing.assert_allclose(np.asarray(g), np.asarray(expect), atol=1e-5)
