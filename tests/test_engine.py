"""Compiled-engine tests: pad-and-mask packing, schedule arrays, and the
stepwise-vs-compiled parity suite (params, losses, accountant)."""

import jax
import numpy as np
import pytest

from repro import optim as O
from repro.core.partition import cnn_adapter
from repro.core.schedule import SCHEDULES, schedule_array
from repro.core.strategies import make_strategy
from repro.core.strategies.base import EpochLog, np_batches
from repro.core.strategies.engine import pack_epoch
from repro.data.synthetic import make_cxr_clients
from repro.models.cnn import DenseNetConfig, build_densenet
from repro.privacy import PrivacyConfig

METHODS = ["fl", "sl_ac", "sl_am", "sflv2_ac", "sflv3_ac"]
DP = PrivacyConfig(noise_multiplier=1.1, clip_norm=1.0)
CUT = PrivacyConfig(cut_noise_std=0.5)


@pytest.fixture(scope="module")
def tiny_setup():
    # uneven hospitals (17/12/9 @ batch 4) => masked steps + remainders
    clients = make_cxr_clients(seed=0, train_per_client=[17, 12, 9],
                               val_per_client=6, test_per_client=7,
                               image_size=16, n_clients=3)
    cfg = DenseNetConfig(growth=4, blocks=(1, 1), stem_ch=8, cut_layer=1)
    return clients, cnn_adapter(build_densenet(cfg))


def _run(method, engine, clients, adapter, privacy=None, epochs=1,
         drop_remainder=True, batch=4):
    st = make_strategy(method, adapter, lambda: O.adam(1e-3), len(clients),
                       privacy=privacy, engine=engine,
                       drop_remainder=drop_remainder)
    state = st.setup(jax.random.key(0))
    rng = np.random.default_rng(0)
    log = None
    for _ in range(epochs):
        state, log = st.run_epoch(state, [c.train for c in clients], rng,
                                  batch)
    return st, state, log


def _assert_parity(method, clients, adapter, privacy=None, epochs=1,
                   drop_remainder=True, atol=1e-5):
    st_a, sa, la = _run(method, "stepwise", clients, adapter, privacy,
                        epochs, drop_remainder)
    st_b, sb, lb = _run(method, "compiled", clients, adapter, privacy,
                        epochs, drop_remainder)
    assert len(la.losses) == len(lb.losses)
    np.testing.assert_allclose(la.losses, lb.losses, atol=atol)
    assert abs(la.mean_loss - lb.mean_loss) < atol
    assert la.client_steps == lb.client_steps
    for i in range(len(clients)):
        pa, pb = st_a.params_for_eval(sa, i), st_b.params_for_eval(sb, i)
        for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=atol)
    ra, rb = st_a.privacy_report(), st_b.privacy_report()
    assert len(ra) == len(rb)
    for x, y in zip(ra, rb):
        assert x["steps"] == y["steps"]
        assert abs(x["epsilon"] - y["epsilon"]) < 1e-9
        assert x["delta"] == y["delta"]


# ---------------------------------------------------------------------------
# parity suite (acceptance: <= 1e-5 on params/losses, privacy on and off)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", METHODS)
def test_parity_plain(method, tiny_setup):
    clients, adapter = tiny_setup
    _assert_parity(method, clients, adapter)


def test_parity_multi_epoch_and_centralized(tiny_setup):
    clients, adapter = tiny_setup
    _assert_parity("fl", clients, adapter, epochs=2)
    _assert_parity("centralized", clients, adapter, epochs=2)


@pytest.mark.parametrize("method", ["fl", "sl_am", "sflv3_ac"])
def test_parity_dp(method, tiny_setup):
    """DP-SGD draws are key-indexed fold-ins: bit-identical across engines,
    and the analytic accountant composition matches step-by-step counts."""
    clients, adapter = tiny_setup
    _assert_parity(method, clients, adapter, privacy=DP)


@pytest.mark.slow
@pytest.mark.parametrize("method", ["sl_ac", "sflv2_ac"])
def test_parity_dp_full_grid(method, tiny_setup):
    clients, adapter = tiny_setup
    _assert_parity(method, clients, adapter, privacy=DP, epochs=2)


def test_parity_cut_noise(tiny_setup):
    clients, adapter = tiny_setup
    _assert_parity("sl_ac", clients, adapter, privacy=CUT)


@pytest.mark.parametrize("method", ["sl_am", "sflv2_ac"])
def test_parity_cut_noise_keep_remainder(method, tiny_setup):
    """Cut-layer noise WITHOUT DP + drop_remainder=False: noise draws are
    per-example (fold_in by row index), so the compiled pad-and-mask rows
    draw exactly what the stepwise short batch draws — padded rows get
    zero noise and zero loss weight."""
    clients, adapter = tiny_setup
    _assert_parity(method, clients, adapter, privacy=CUT,
                   drop_remainder=False)


def test_parity_fl_secagg(tiny_setup):
    """secagg keeps the host-side masked aggregation on the compiled path
    (per-client uploads must exist to be masked)."""
    clients, adapter = tiny_setup
    _assert_parity("fl", clients, adapter,
                   privacy=PrivacyConfig(secagg=True))


@pytest.mark.parametrize("method", ["fl", "sl_am"])
def test_parity_keep_remainder(method, tiny_setup):
    """drop_remainder=False: stepwise short batches == compiled
    pad-and-mask per-example weights."""
    clients, adapter = tiny_setup
    _assert_parity(method, clients, adapter, drop_remainder=False)


@pytest.mark.parametrize("method", ["fl", "sl_am"])
def test_parity_dp_keep_remainder(method, tiny_setup):
    """DP + drop_remainder=False on the compiled engine: weighted
    per-example clipping makes padded rows exact no-ops, so the stepwise
    short-batch DP step (the parity oracle) is matched — losses, params,
    AND accountant epsilon."""
    clients, adapter = tiny_setup
    _assert_parity(method, clients, adapter, privacy=DP,
                   drop_remainder=False)


# ---------------------------------------------------------------------------
# whole-run programs: Strategy.run(n_epochs) as ONE XLA call
# ---------------------------------------------------------------------------

RUN_METHODS = ["centralized", "fl", "sl_am", "sflv2_ac", "sflv3_ac"]


def _whole_run(method, engine, clients, adapter, privacy=None, epochs=3,
               batch=4, drop_remainder=True):
    st = make_strategy(method, adapter, lambda: O.adam(1e-3), len(clients),
                       privacy=privacy, engine=engine,
                       drop_remainder=drop_remainder)
    state = st.setup(jax.random.key(0))
    state, logs = st.run(state, [c.train for c in clients],
                         np.random.default_rng(0), batch, epochs)
    return st, state, logs


def _assert_run_parity(method, clients, adapter, privacy=None, epochs=3,
                       atol=1e-5):
    st_a, sa, la = _whole_run(method, "stepwise", clients, adapter, privacy,
                              epochs)
    st_b, sb, lb = _whole_run(method, "compiled", clients, adapter, privacy,
                              epochs)
    assert len(la) == len(lb) == epochs
    for ea, eb in zip(la, lb):
        assert len(ea.losses) == len(eb.losses)
        np.testing.assert_allclose(ea.losses, eb.losses, atol=atol)
        assert ea.client_steps == eb.client_steps
        assert ea.weights == eb.weights
    for i in range(len(clients)):
        pa, pb = st_a.params_for_eval(sa, i), st_b.params_for_eval(sb, i)
        for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=atol)
    ra, rb = st_a.privacy_report(), st_b.privacy_report()
    assert len(ra) == len(rb)
    for x, y in zip(ra, rb):
        assert x["steps"] == y["steps"]
        assert abs(x["epsilon"] - y["epsilon"]) < 1e-9


@pytest.mark.parametrize("method", RUN_METHODS)
def test_run_parity_plain(method, tiny_setup):
    """3-epoch whole-run program == stepwise epoch loop (<= 1e-5)."""
    clients, adapter = tiny_setup
    _assert_run_parity(method, clients, adapter)


@pytest.mark.parametrize("method", ["fl", "sl_am", "sflv3_ac"])
def test_run_parity_dp(method, tiny_setup):
    clients, adapter = tiny_setup
    _assert_run_parity(method, clients, adapter, privacy=DP, epochs=2)


@pytest.mark.slow
@pytest.mark.parametrize("method", ["centralized", "sl_ac", "sflv2_ac"])
def test_run_parity_dp_full_grid(method, tiny_setup):
    clients, adapter = tiny_setup
    _assert_run_parity(method, clients, adapter, privacy=DP, epochs=2)


def test_run_parity_cut_noise(tiny_setup):
    clients, adapter = tiny_setup
    _assert_run_parity("sl_ac", clients, adapter, privacy=CUT, epochs=2)


@pytest.mark.parametrize("method", RUN_METHODS)
def test_run_is_one_program(method, tiny_setup):
    """A 3-epoch compiled run is ONE XLA dispatch: the whole-run program
    is invoked exactly once, traced/compiled exactly once, and no
    per-epoch program is ever built."""
    clients, adapter = tiny_setup
    st, _, logs = _whole_run(method, "compiled", clients, adapter)
    assert len(logs) == 3
    assert getattr(st, "_run_calls", 0) == 1
    assert not hasattr(st, "_epoch_c"), "per-epoch program was dispatched"
    run_fn = getattr(st, "_run_c", None) or getattr(st, "_run3_c", None)
    assert run_fn is not None
    if hasattr(run_fn, "_cache_size"):
        assert run_fn._cache_size() == 1
    # a second run reuses the same compiled program (no retrace)
    st.run(st.setup(jax.random.key(1)), [c.train for c in clients],
           np.random.default_rng(1), 4, 3)
    assert st._run_calls == 2
    if hasattr(run_fn, "_cache_size"):
        assert run_fn._cache_size() == 1


def test_run_secagg_falls_back_to_per_round(tiny_setup):
    """Secagg's masked uploads are host-side: run() keeps per-epoch
    dispatch but still matches the stepwise reference."""
    clients, adapter = tiny_setup
    priv = PrivacyConfig(secagg=True)
    _assert_run_parity("fl", clients, adapter, privacy=priv, epochs=2)
    st, _, _ = _whole_run("fl", "compiled", clients, adapter, privacy=priv,
                          epochs=2)
    assert getattr(st, "_run_calls", 0) == 0     # whole-run path not taken


def test_run_empty_epochs(tiny_setup):
    clients, adapter = tiny_setup
    st = make_strategy("fl", adapter, lambda: O.adam(1e-3), len(clients))
    state = st.setup(jax.random.key(0))
    state, logs = st.run(state, [c.train for c in clients],
                         np.random.default_rng(0), 4, 0)
    assert logs == []


@pytest.mark.parametrize("method", ["fl", "sl_am"])
def test_run_keep_remainder_parity(method, tiny_setup):
    """drop_remainder=False whole-run: stepwise short batches == compiled
    pad-and-mask per-example weights, across every round."""
    clients, adapter = tiny_setup
    st_a, sa, la = _whole_run(method, "stepwise", clients, adapter,
                              drop_remainder=False)
    st_b, sb, lb = _whole_run(method, "compiled", clients, adapter,
                              drop_remainder=False)
    for ea, eb in zip(la, lb):
        np.testing.assert_allclose(ea.losses, eb.losses, atol=1e-5)
        assert ea.weights == eb.weights
    for i in range(len(clients)):
        for a, b in zip(jax.tree.leaves(st_a.params_for_eval(sa, i)),
                        jax.tree.leaves(st_b.params_for_eval(sb, i))):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=1e-5)


# ---------------------------------------------------------------------------
# np_batches remainder handling (satellite)
# ---------------------------------------------------------------------------

def test_np_batches_remainder():
    data = {"x": np.arange(11)[:, None], "label": np.arange(11)}
    dropped = np_batches(data, 4, None)
    assert [len(b["label"]) for b in dropped] == [4, 4]      # 3 lost
    kept = np_batches(data, 4, None, drop_remainder=False)
    assert [len(b["label"]) for b in kept] == [4, 4, 3]
    assert sorted(np.concatenate([b["label"] for b in kept])) == list(
        range(11))
    # shuffles must be identical across the two modes
    a = np_batches(data, 4, np.random.default_rng(7))
    b = np_batches(data, 4, np.random.default_rng(7),
                   drop_remainder=False)
    np.testing.assert_array_equal(a[0]["label"], b[0]["label"])


def test_pack_epoch_matches_np_batches():
    data = [{"x": np.arange(10, dtype=np.float32)[:, None],
             "label": np.arange(10)},
            {"x": np.arange(5, dtype=np.float32)[:, None],
             "label": np.arange(5)}]
    packed = pack_epoch(data, 2, np.random.default_rng(3))
    assert packed.mask.shape == (2, 5)
    assert packed.n_batches == [5, 2]
    assert packed.mask[1].tolist() == [True, True, False, False, False]
    # same rng stream => identical batch contents as the stepwise path
    # (ONE generator consumed in hospital order, as strategies do)
    rng = np.random.default_rng(3)
    stepwise = [np_batches(d, 2, rng) for d in data]
    for c, bs in enumerate(stepwise):
        for j, b in enumerate(bs):
            np.testing.assert_array_equal(
                packed.batches["label"][c, j], b["label"])
    # padding rows are flagged invalid, remainder kept under pad-and-mask
    kept = pack_epoch(data, 3, np.random.default_rng(0),
                      drop_remainder=False)
    assert kept.n_batches == [4, 2]
    assert kept.ex_weights[0, 3].tolist() == [1.0, 0.0, 0.0]
    assert kept.step_examples[0] == [3, 3, 3, 1]


def test_schedule_array_matches_schedules():
    nb = [3, 1, 2]
    for name in ("ac", "am"):
        arr = schedule_array(name, nb)
        assert arr.dtype == np.int32 and arr.shape == (6, 2)
        assert [tuple(r) for r in arr] == SCHEDULES[name](nb)


# ---------------------------------------------------------------------------
# EpochLog statistics (satellite)
# ---------------------------------------------------------------------------

def test_epochlog_mask_aware_mean():
    log = EpochLog([1.0, 3.0], 2)
    assert log.mean_loss == 2.0
    weighted = EpochLog([1.0, 3.0], 2, weights=[3, 1])
    assert weighted.mean_loss == pytest.approx(1.5)
    assert EpochLog([], 0).mean_loss != EpochLog([], 0).mean_loss  # nan


def test_epochlog_stats_identical_across_engines(tiny_setup):
    clients, adapter = tiny_setup
    _, _, la = _run("fl", "stepwise", clients, adapter,
                    drop_remainder=False)
    _, _, lb = _run("fl", "compiled", clients, adapter,
                    drop_remainder=False)
    assert la.weights == lb.weights
    assert la.client_steps == lb.client_steps == [5, 3, 3]
    assert abs(la.mean_loss - lb.mean_loss) < 1e-6


# ---------------------------------------------------------------------------
# engine guards
# ---------------------------------------------------------------------------

def test_engine_guards(tiny_setup):
    clients, adapter = tiny_setup
    with pytest.raises(ValueError):
        make_strategy("fl", adapter, lambda: O.adam(1e-3), 3,
                      engine="warp")
    # cut-layer noise draws are per-example (batch-length independent), so
    # both privacy modes accept kept remainder batches
    make_strategy("sl_ac", adapter, lambda: O.adam(1e-3), 3,
                  privacy=CUT, engine="compiled", drop_remainder=False)
    make_strategy("fl", adapter, lambda: O.adam(1e-3), 3, privacy=DP,
                  engine="compiled", drop_remainder=False)
    with pytest.raises(ValueError):                 # batch-synchronous v3
        make_strategy("sflv3_ac", adapter, lambda: O.adam(1e-3), 3,
                      drop_remainder=False)


# ---------------------------------------------------------------------------
# batched eval (satellite): one dispatch for every hospital
# ---------------------------------------------------------------------------

def test_scores_all_matches_per_hospital(tiny_setup):
    clients, adapter = tiny_setup
    st, state, _ = _run("sl_ac", "compiled", clients, adapter)
    datas = [c.test for c in clients]
    batched = st.scores_all(state, datas, batch_size=4)
    for i, d in enumerate(datas):
        assert len(batched[i]) == len(d["label"]) == 7   # partial kept
        single = st.scores(state, i, d, batch_size=4)
        np.testing.assert_allclose(batched[i], single, atol=1e-6)
    m = st.evaluate(state, clients, "test", batch_size=4)
    assert 0.0 <= m["auroc"] <= 1.0


@pytest.mark.parametrize("drop_remainder", [True, False])
def test_transport_accounting_compiled_matches_stepwise(drop_remainder,
                                                        tiny_setup):
    """Byte accounting is engine-independent — including kept remainder
    batches, which the compiled path must meter at their TRUE short shape
    rather than the padded full-batch shape."""
    from repro.wire import Transport
    clients, adapter = tiny_setup
    byt = {}
    for engine in ("stepwise", "compiled"):
        tp = Transport("identity")
        st = make_strategy("sl_am", adapter, lambda: O.adam(1e-3),
                           len(clients), transport=tp, engine=engine,
                           drop_remainder=drop_remainder)
        state = st.setup(jax.random.key(0))
        st.run_epoch(state, [c.train for c in clients],
                     np.random.default_rng(0), 4)
        byt[engine] = (tp.steps, tp.bytes_on_wire)
    assert byt["stepwise"] == byt["compiled"]
