"""repro.wire — codecs, network models, simulator, transport hook."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.comm import client_batch_counts, comm_per_epoch, leg_sizes
from repro.core.partition import cnn_adapter
from repro.kernels.act_compress.act_compress import (dequantize_pallas,
                                                     quantize_pallas)
from repro.kernels.act_compress.ref import (dequantize_ref, quantize_ref,
                                            roundtrip_ref)
from repro.models.cnn import DenseNetConfig, build_densenet
from repro.wire import (NetworkModel, SCENARIOS, Transport, boundary_error,
                        build_transfers, make_codec, make_network, replay,
                        simulate, straggler_sensitivity,
                        timeline_from_accounting, tree_wire_bytes)

CFG = DenseNetConfig(growth=8, blocks=(3, 6), stem_ch=8, cut_layer=1)
N_TRAIN = [48, 32, 48, 16, 32]
N_VAL = [16] * 5
BS = 8


def _adapter(nls=False):
    return cnn_adapter(build_densenet(CFG, nls=nls))


def _batch(n=BS):
    return {"image": np.zeros((n, 16, 16, 1), np.float32),
            "label": np.zeros((n,), np.float32)}


# ---------------------------------------------------------------------------
# act_compress kernel: pallas interpret-mode vs ref oracle (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,d", [(8, 16), (256, 64), (512, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_act_compress_pallas_roundtrip_matches_ref(t, d, dtype):
    x = jax.random.normal(jax.random.key(0), (t, d)).astype(dtype) * 3.0
    q, s = quantize_pallas(x, interpret=True)
    q_ref, s_ref = quantize_ref(x)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=1e-6)
    # interpret-mode XLA may rewrite x/scale as x*(1/scale): allow ties to
    # land one quantization level apart
    assert np.abs(np.asarray(q, np.int32)
                  - np.asarray(q_ref, np.int32)).max() <= 1
    out = dequantize_pallas(q, s, dtype, interpret=True)
    ref = dequantize_ref(q_ref, s_ref, dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=float(np.asarray(s_ref).max()))


def test_act_compress_roundtrip_error_bounded_by_row_absmax():
    x = jax.random.normal(jax.random.key(1), (64, 32), jnp.float32) * 5.0
    r = np.asarray(roundtrip_ref(x), np.float32)
    amax = np.abs(np.asarray(x)).max(axis=1, keepdims=True)
    # absmax int8: |err| <= scale/2 = amax / 254 per element (+ rounding eps)
    assert (np.abs(np.asarray(x) - r) <= amax / 254 + 1e-6).all()


# ---------------------------------------------------------------------------
# codec byte counts are exact
# ---------------------------------------------------------------------------

def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def test_identity_codec_bytes():
    c = make_codec("identity")
    assert c.wire_bytes(_spec((4, 8, 8, 3))) == 4 * 8 * 8 * 3 * 4
    assert c.wire_bytes(_spec((16, 7), jnp.bfloat16)) == 16 * 7 * 2


def test_bf16_codec_bytes():
    assert make_codec("bf16").wire_bytes(_spec((5, 10))) == 5 * 10 * 2


def test_int8_codec_bytes_include_row_scales():
    c = make_codec("int8")
    # (rows, D): 1 byte/elem + 4-byte f32 scale per row
    assert c.wire_bytes(_spec((32, 64))) == 32 * 64 + 4 * 32
    assert c.wire_bytes(_spec((2, 4, 8))) == 2 * 4 * 8 + 4 * 2 * 4


def test_topk_codec_bytes():
    c = make_codec("topk:0.1")
    n = 1000
    k = 100                       # ceil(0.1 * 1000)
    assert c.wire_bytes(_spec((10, 100))) == k * (4 + 4)
    # fraction rounds up and never drops below one element
    assert make_codec("topk:0.001").wire_bytes(_spec((10,))) == 1 * (4 + 4)


def test_codec_encode_matches_wire_bytes_accounting():
    """The declared wire_bytes equal the payload's actual nbytes."""
    x = jax.random.normal(jax.random.key(2), (32, 64), jnp.float32)
    spec = _spec((32, 64))
    for name in ("identity", "bf16", "int8", "topk:0.25"):
        c = make_codec(name)
        payload = c.encode(x)
        nbytes = sum(np.asarray(l).nbytes for l in jax.tree.leaves(payload))
        assert nbytes == c.wire_bytes(spec), name


def test_make_codec_rejects_unknown():
    with pytest.raises(KeyError):
        make_codec("gzip")


# ---------------------------------------------------------------------------
# codec error bounds + decode fidelity
# ---------------------------------------------------------------------------

def test_codec_error_bounds():
    x = jax.random.normal(jax.random.key(3), (64, 32), jnp.float32)
    assert make_codec("identity").error(x)["max_abs"] == 0.0
    assert make_codec("bf16").error(x)["rel_l2"] < 1e-2
    assert make_codec("int8").error(x)["rel_l2"] < 2e-2
    # a full-fraction top-k is lossless
    assert make_codec("topk:1.0").error(x)["max_abs"] == 0.0


def test_topk_keeps_largest_magnitudes():
    x = jnp.asarray(np.r_[np.zeros(95), np.arange(1.0, 6.0)],
                    jnp.float32).reshape(10, 10)
    c = make_codec("topk:0.05")
    r = np.asarray(c.roundtrip(x)).reshape(-1)
    np.testing.assert_array_equal(r[-5:], np.arange(1.0, 6.0))
    assert (r[:-5] == 0).all()


def test_codec_decode_roundtrip_consistent():
    """decode(encode(x)) == roundtrip(x) for every codec."""
    x = jax.random.normal(jax.random.key(4), (16, 24), jnp.float32)
    for name in ("identity", "bf16", "int8", "topk:0.2"):
        c = make_codec(name)
        via_payload = np.asarray(c.decode(c.encode(x), x), np.float32)
        via_rt = np.asarray(c.roundtrip(x), np.float32)
        np.testing.assert_allclose(via_payload, via_rt, rtol=1e-6,
                                   atol=1e-6, err_msg=name)


def test_lossy_codecs_backprop_straight_through():
    x = jax.random.normal(jax.random.key(5), (8, 16), jnp.float32)
    for name in ("bf16", "int8", "topk:0.1"):
        g = jax.grad(lambda y: (make_codec(name).roundtrip(y) ** 0 * y)
                     .sum())(x)
        np.testing.assert_allclose(np.asarray(g), 1.0, err_msg=name)


# ---------------------------------------------------------------------------
# network models
# ---------------------------------------------------------------------------

def test_transfer_time_scales_with_bytes_and_bandwidth():
    rng = np.random.default_rng(0)
    net = NetworkModel("t", bandwidth_bps=1e6, rtt_s=0.0)
    assert net.transfer_time(1e6, rng) == pytest.approx(8.0)
    assert net.transfer_time(2e6, rng) == pytest.approx(16.0)
    assert net.transfer_time(1e6, rng, mult=4.0) == pytest.approx(32.0)


def test_scenarios_ordered_by_speed():
    rng = np.random.default_rng(0)
    nb = 1e7
    t = {k: dataclasses.replace(v, jitter=0.0).transfer_time(nb, rng)
         for k, v in SCENARIOS.items()}
    assert t["lan"] < t["hospital_wan"] < t["cellular"]


def test_straggler_multipliers_and_removal():
    net = SCENARIOS["cellular"]
    mult = net.client_multipliers(1000, np.random.default_rng(0))
    frac = (mult > 1).mean()
    assert 0.2 < frac < 0.5                      # ~1/3 of clients
    clean = net.without_stragglers()
    assert (clean.client_multipliers(100, np.random.default_rng(0)) == 1).all()
    assert net.straggler_frac > 0                # frozen original untouched


def test_make_network_rejects_unknown():
    with pytest.raises(KeyError):
        make_network("carrier_pigeon")


# ---------------------------------------------------------------------------
# simulator: byte conservation vs the analytic profile (the Table-4 gate)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["centralized", "fl", "sl_ac", "sl_am",
                                    "sflv2_ac", "sflv3_ac", "sflv1_ac"])
@pytest.mark.parametrize("nls", [False, True])
def test_simulated_bytes_match_analytic_comm(method, nls):
    ad = _adapter(nls)
    r = simulate(method, ad, _batch(), N_TRAIN, N_VAL, BS, "identity",
                 "lan", keep_events=False)
    analytic = comm_per_epoch(method, ad, _batch(), N_TRAIN, N_VAL,
                              BS).bytes_per_epoch
    assert r.bytes_on_wire == pytest.approx(analytic, rel=0.01)
    assert r.bytes_raw == pytest.approx(analytic)


def test_simulated_breakdown_matches_analytic_breakdown():
    ad = _adapter()
    r = simulate("sflv2_ac", ad, _batch(), N_TRAIN, N_VAL, BS, "identity",
                 "lan", keep_events=False)
    analytic = comm_per_epoch("sflv2_ac", ad, _batch(), N_TRAIN, N_VAL, BS)
    for tag, b in analytic.breakdown.items():
        assert r.breakdown[tag] == pytest.approx(b), tag


@pytest.mark.parametrize("method", ["sl_ac", "sflv3_ac"])
def test_nls_breakdown_keys_match_comm_and_directions(method):
    """The NLS hidden legs were tagged with swapped names relative to
    their physical directions: per-tag buckets must use the SAME keys as
    ``comm_per_epoch`` and every directional tag must agree with the
    transfer's direction (hidden activations flow DOWN to the client-held
    tail, their gradients back UP)."""
    ad = _adapter(nls=True)
    r = simulate(method, ad, _batch(), N_TRAIN, N_VAL, BS, "identity",
                 "lan")
    analytic = comm_per_epoch(method, ad, _batch(), N_TRAIN, N_VAL, BS)
    assert set(r.breakdown) == set(analytic.breakdown)
    for tag, b in analytic.breakdown.items():
        assert r.breakdown[tag] == pytest.approx(b), tag
    for e in r.events:
        if e.tag.endswith("_down"):
            assert e.direction == "down", e.tag
        elif e.tag.endswith("_up"):
            assert e.direction == "up", e.tag


def test_compression_ratio_nan_when_nothing_crossed_the_wire():
    """Both zero-byte paths report nan, not a bogus bytes_raw/1 ratio."""
    ad = _adapter()
    r = simulate("centralized", ad, _batch(), N_TRAIN, N_VAL, BS,
                 "identity", "lan", keep_events=False)
    assert r.bytes_on_wire == 0
    assert np.isnan(r.compression_ratio)
    tp = Transport("int8")
    assert np.isnan(tp.compression_ratio)


def test_codec_shrinks_simulated_bytes_and_wallclock():
    ad = _adapter()
    args = (ad, _batch(), N_TRAIN, N_VAL, BS)
    ident = simulate("sl_ac", *args, "identity", "hospital_wan",
                     keep_events=False)
    int8 = simulate("sl_ac", *args, "int8", "hospital_wan",
                    keep_events=False)
    assert int8.bytes_on_wire < 0.5 * ident.bytes_on_wire
    assert int8.wall_clock_s < ident.wall_clock_s
    assert int8.compression_ratio > 2.0


def test_parallel_sflv3_faster_than_sequential_sl():
    """Same bytes, but SFLv3 overlaps client links; SL serializes."""
    ad = _adapter()
    net = NetworkModel("flat", bandwidth_bps=1e8, rtt_s=1e-3)   # no jitter
    args = (ad, _batch(), N_TRAIN, N_VAL, BS)
    sl = simulate("sl_ac", *args, "identity", net, keep_events=False)
    v3 = simulate("sflv3_ac", *args, "identity", net, keep_events=False)
    assert v3.bytes_on_wire == pytest.approx(sl.bytes_on_wire)
    assert v3.wall_clock_s < sl.wall_clock_s


def test_straggler_hits_barrier_methods_harder():
    """One 10x-slow client: SFLv3 pays it every step, SL only on its turn."""
    ad = _adapter()
    net = NetworkModel("flat", bandwidth_bps=1e8, rtt_s=0.0)
    mult = np.array([1.0, 1.0, 10.0, 1.0, 1.0])
    args = (ad, _batch(), N_TRAIN, N_VAL, BS)
    sl_c = simulate("sl_ac", *args, "identity", net, multipliers=mult,
                    keep_events=False)
    sl_0 = simulate("sl_ac", *args, "identity", net, keep_events=False)
    v3_c = simulate("sflv3_ac", *args, "identity", net, multipliers=mult,
                    keep_events=False)
    v3_0 = simulate("sflv3_ac", *args, "identity", net, keep_events=False)
    sl_ratio = sl_c.wall_clock_s / sl_0.wall_clock_s
    v3_ratio = v3_c.wall_clock_s / v3_0.wall_clock_s
    assert v3_ratio > sl_ratio > 1.0


def test_straggler_sensitivity_at_least_one():
    ad = _adapter()
    s = straggler_sensitivity("sflv3_ac", ad, _batch(), N_TRAIN, N_VAL, BS,
                              "identity", "cellular", seed=0)
    assert s >= 1.0


def test_event_timeline_is_consistent():
    ad = _adapter()
    r = simulate("sflv3_ac", ad, _batch(), N_TRAIN, N_VAL, BS, "identity",
                 "hospital_wan")
    assert r.events, "expected a non-empty event timeline"
    for c in range(r.n_clients):
        tl = r.timeline(c)
        assert all(e.client == c for e in tl)
        # one client's link carries one transfer at a time
        for a, b in zip(tl, tl[1:]):
            assert b.t_start >= a.t_start
        assert sum(e.t_end - e.t_start for e in tl) == pytest.approx(
            r.per_client[c]["busy_s"])
    assert max(e.t_end for e in r.events) == pytest.approx(r.wall_clock_s)


def test_replay_detects_cyclic_dag():
    from repro.wire.simulator import Transfer
    cyc = [Transfer(0, 0, 1.0, "up", "t", (1,)),
           Transfer(1, 0, 1.0, "down", "t", (0,))]
    with pytest.raises(RuntimeError):
        replay(cyc, make_network("lan"), 1)


def test_build_transfers_unknown_method():
    with pytest.raises(KeyError):
        build_transfers("gossip", _adapter(), _batch(), N_TRAIN, N_VAL, BS)


# ---------------------------------------------------------------------------
# comm.py shared primitives
# ---------------------------------------------------------------------------

def test_client_batch_counts_match_comm_profile():
    tr, va = client_batch_counts(N_TRAIN, N_VAL, BS)
    assert tr == [n // BS for n in N_TRAIN]
    assert all(v >= 1 for v in va)
    # tiny val shard still ships one batch
    assert client_batch_counts([BS], [1], BS)[1] == [1]


def test_leg_sizes_with_codec_shrink_only_activations():
    ad = _adapter()
    raw = leg_sizes(ad, _batch())
    c8 = leg_sizes(ad, _batch(), codec=make_codec("int8"))
    assert c8["model"] == raw["model"]
    assert c8["client_seg"] == raw["client_seg"]
    assert c8["act_fm"] < raw["act_fm"]
    assert c8["act_fm_raw"] == raw["act_fm_raw"]


def test_comm_per_epoch_codec_kwarg():
    ad = _adapter()
    raw = comm_per_epoch("sl_ac", ad, _batch(), N_TRAIN, N_VAL, BS)
    c8 = comm_per_epoch("sl_ac", ad, _batch(), N_TRAIN, N_VAL, BS,
                        codec=make_codec("int8"))
    assert 0 < c8.bytes_per_epoch < 0.5 * raw.bytes_per_epoch


# ---------------------------------------------------------------------------
# transport hook in real training
# ---------------------------------------------------------------------------

def test_transport_accounting_matches_analytic_train_legs():
    from repro import optim as O
    from repro.core.strategies import make_strategy
    ad = _adapter()
    n, bs = 16, 8
    data = [{"image": np.random.default_rng(c).normal(
                 0, 1, (n, 16, 16, 1)).astype(np.float32),
             "label": np.zeros((n,), np.float32)} for c in range(2)]
    tp = Transport("identity")
    strat = make_strategy("sl_ac", ad, lambda: O.adam(1e-3), 2, transport=tp)
    state = strat.setup(jax.random.key(0))
    state, log = strat.run_epoch(state, data, np.random.default_rng(0), bs)
    eb = {k: v[:bs] for k, v in data[0].items()}
    prof = comm_per_epoch("sl_ac", ad, eb, [n, n], [0, 0], bs)
    train_bytes = (prof.breakdown["train_act_up"]
                   + prof.breakdown["train_grad_down"])
    assert tp.bytes_on_wire == pytest.approx(train_bytes)
    assert tp.bytes_raw == pytest.approx(train_bytes)
    assert tp.steps == log.steps


@pytest.mark.slow
def test_training_with_int8_transport_runs_and_compresses():
    from repro import optim as O
    from repro.core.strategies import make_strategy
    ad = _adapter()
    n, bs = 16, 8
    data = [{"image": np.random.default_rng(c).normal(
                 0, 1, (n, 16, 16, 1)).astype(np.float32),
             "label": (np.arange(n) % 2).astype(np.float32)}
            for c in range(2)]
    tp = Transport("int8")
    strat = make_strategy("sflv3_ac", ad, lambda: O.adam(1e-3), 2,
                          transport=tp)
    state = strat.setup(jax.random.key(0))
    state, log = strat.run_epoch(state, data, np.random.default_rng(0), bs)
    assert np.isfinite(log.mean_loss)
    assert tp.compression_ratio > 2.0
    assert tp.bytes_on_wire > 0


def test_transport_cache_keyed_on_adapter_boundary():
    """One Transport shared across adapters with different cut points must
    not reuse the first adapter's boundary sizes (the shape cache used to
    be keyed on batch shapes alone)."""
    ad1 = cnn_adapter(build_densenet(CFG))                       # cut 1
    ad2 = cnn_adapter(build_densenet(
        dataclasses.replace(CFG, cut_layer=2)))                  # cut 2
    b = _batch()
    solo1, solo2 = Transport("identity"), Transport("identity")
    solo1.account(ad1, b)
    solo2.account(ad2, b)
    assert solo1.bytes_on_wire != solo2.bytes_on_wire
    shared = Transport("identity")
    shared.account(ad1, b)
    shared.account(ad2, b)
    assert shared.bytes_on_wire == pytest.approx(
        solo1.bytes_on_wire + solo2.bytes_on_wire)


# ---------------------------------------------------------------------------
# analytic -> timeline bridge: trained accounting replays to simulate()'s
# exact wall-clock and per-tag bytes, whichever engine trained
# ---------------------------------------------------------------------------

def _train_with_transport(method, engine, adapter, data, bs):
    import jax as _jax
    from repro import optim as O
    from repro.core.strategies import make_strategy
    tp = Transport("identity")
    strat = make_strategy(method, adapter, lambda: O.adam(1e-3), len(data),
                          transport=tp, engine=engine)
    state = strat.setup(_jax.random.key(0))
    strat.run(state, data, np.random.default_rng(0), bs, 1)
    return tp


@pytest.mark.parametrize("method", ["sl_am", "sflv2_ac", "sflv3_ac"])
def test_timeline_from_accounting_engine_independent(method):
    """Acceptance gate: simulate() and the trained-transport replay agree
    exactly (identity codec, same seed) whether the accounting came from
    the stepwise per-step path or the compiled analytic path."""
    ad = _adapter()
    n, bs = [24, 16, 8], 8
    data = [{"image": np.random.default_rng(c).normal(
                 0, 1, (nn, 16, 16, 1)).astype(np.float32),
             "label": (np.arange(nn) % 2).astype(np.float32)}
            for c, nn in enumerate(n)]
    n_val = [8, 8, 8]
    results = {}
    for engine in ("stepwise", "compiled"):
        tp = _train_with_transport(method, engine, ad, data, bs)
        assert len(tp.epoch_log) == 1
        results[engine] = timeline_from_accounting(
            tp, n_val=n_val, batch_size=bs, network="hospital_wan", seed=3)
    eb = {k: v[:bs] for k, v in data[0].items()}
    sim = simulate(method, ad, eb, n, n_val, bs, "identity",
                   "hospital_wan", seed=3)
    for r in results.values():
        assert r.wall_clock_s == sim.wall_clock_s
        assert r.breakdown == sim.breakdown
        assert r.bytes_on_wire == sim.bytes_on_wire
        assert r.bytes_raw == sim.bytes_raw


def test_timeline_from_accounting_multi_epoch_bytes():
    """An E-epoch trained run replays to exactly E x the per-epoch
    analytic profile (identity codec) — train-only without n_val, full
    profile with per-epoch validation legs."""
    ad = _adapter()
    n, bs, E = [24, 16, 8], 8, 3
    data = [{"image": np.random.default_rng(c).normal(
                 0, 1, (nn, 16, 16, 1)).astype(np.float32),
             "label": (np.arange(nn) % 2).astype(np.float32)}
            for c, nn in enumerate(n)]
    n_val = [8, 8, 8]
    import jax as _jax
    from repro import optim as O
    from repro.core.strategies import make_strategy
    tp = Transport("identity")
    strat = make_strategy("sl_am", ad, lambda: O.adam(1e-3), len(data),
                          transport=tp)            # default engine
    state = strat.setup(_jax.random.key(0))
    strat.run(state, data, np.random.default_rng(0), bs, E)
    assert len(tp.epoch_log) == E
    eb = {k: v[:bs] for k, v in data[0].items()}
    full = comm_per_epoch("sl_am", ad, eb, n, n_val, bs)
    with_val = timeline_from_accounting(tp, n_val=n_val, batch_size=bs,
                                        network="lan", keep_events=False)
    assert with_val.bytes_on_wire == E * full.bytes_per_epoch
    train_only = timeline_from_accounting(tp, network="lan",
                                          keep_events=False)
    train_bytes = sum(v for k, v in full.breakdown.items()
                      if not k.startswith("val_"))
    assert train_only.bytes_on_wire == E * train_bytes
    # and the transport's own counters agree (identity codec, no
    # wrap-around for sl)
    assert tp.bytes_on_wire == train_only.bytes_on_wire


def test_timeline_from_accounting_empty_transport():
    r = timeline_from_accounting(Transport("identity"), network="lan")
    assert r.bytes_on_wire == 0 and r.wall_clock_s == 0
    assert np.isnan(r.compression_ratio)


def test_sflv3_rejects_client_without_a_full_batch():
    """Batch-synchronous SFLv3 needs >= 1 batch per client — clear error,
    not the ZeroDivisionError the wrap-around modulo would raise."""
    from repro import optim as O
    from repro.core.strategies import make_strategy
    ad = _adapter()
    data = [{"image": np.zeros((n, 16, 16, 1), np.float32),
             "label": np.zeros((n,), np.float32)} for n in (16, 4)]
    strat = make_strategy("sflv3_ac", ad, lambda: O.adam(1e-3), 2)
    state = strat.setup(jax.random.key(0))
    with pytest.raises(ValueError, match="fewer than batch_size"):
        strat.run_epoch(state, data, np.random.default_rng(0), 8)


def test_transport_rejected_for_methods_without_cut_layer():
    from repro import optim as O
    from repro.core.strategies import make_strategy
    with pytest.raises(ValueError):
        make_strategy("fl", _adapter(), lambda: O.adam(1e-3), 2,
                      transport=Transport("int8"))


def test_boundary_error_reports_per_boundary_leaves():
    ad = _adapter(nls=True)
    params = ad.init(jax.random.key(0))
    errs = boundary_error("int8", ad, params, _batch())
    assert set(errs) == {"front->", "middle->"}
    for v in errs.values():
        for e in v:
            assert 0 <= e["rel_l2"] < 0.1


def test_tree_wire_bytes_sums_leaves():
    c = make_codec("identity")
    tree = {"a": _spec((2, 3)), "b": [_spec((4,), jnp.bfloat16)]}
    assert tree_wire_bytes(c, tree) == 2 * 3 * 4 + 4 * 2
