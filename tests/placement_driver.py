"""Multi-device placement driver — run by tests/test_placement.py under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

For every requested method and hospital count, trains the whole-run
compiled program twice — ``shard=False`` (single default device) and
``shard=True`` (hospital axis on the 8-device "hosp" mesh, padded with
phantom hospitals when the count does not divide) — and asserts:

  * params / losses / EpochLog stats parity ≤ 1e-5,
  * DP accountant epsilon parity (exact) where privacy is on,
  * wire byte-meter parity (exact) where a transport is attached,
  * batched-eval ``scores_all`` parity ≤ 1e-5,
  * the run batch stacks AND the stacked client state are REALLY placed
    on the "hosp" mesh (``.sharding`` spec inspection — no silent
    replication).

Prints ``PLACEMENT_OK`` on success (the pytest wrapper greps for it).
"""

import argparse
import sys

import jax
import numpy as np

from repro import optim as O
from repro.core.partition import cnn_adapter
from repro.core.strategies import make_strategy
from repro.data.synthetic import make_cxr_clients
from repro.models.cnn import DenseNetConfig, build_densenet
from repro.privacy import PrivacyConfig

ATOL = 1e-5
EPOCHS = 2
BATCH = 4


def build(n_clients):
    # uneven cohorts => masked steps; n=5 on 8 devices => 3 phantoms
    sizes = [13, 9, 11, 8, 10, 12, 9, 11][:n_clients]
    clients = make_cxr_clients(seed=0, n_clients=n_clients,
                               train_per_client=sizes, val_per_client=4,
                               test_per_client=6, image_size=8)
    cfg = DenseNetConfig(growth=2, blocks=(1, 1), stem_ch=4, cut_layer=1)
    return clients, cnn_adapter(build_densenet(cfg))


def run_once(method, clients, adapter, shard):
    privacy = (PrivacyConfig(noise_multiplier=1.1, clip_norm=1.0)
               if method == "fl" else None)
    transport = None
    if method.startswith(("sl", "sflv")):
        from repro.wire import Transport
        transport = Transport("identity")
    st = make_strategy(method, adapter, lambda: O.adam(1e-3), len(clients),
                       privacy=privacy, transport=transport, shard=shard)
    state = st.setup(jax.random.key(0))
    state, logs = st.run(state, [c.train for c in clients],
                         np.random.default_rng(0), BATCH, EPOCHS)
    return st, state, logs, transport


def assert_hosp_sharded(x, c_pad, axis, what):
    """The given axis is split across the 8-device hosp mesh."""
    sh = x.sharding
    assert not sh.is_fully_replicated, f"{what}: silently replicated"
    shard_shape = sh.shard_shape(x.shape)
    assert shard_shape[axis] == c_pad // jax.device_count(), \
        f"{what}: shard shape {shard_shape} for {x.shape}"
    if hasattr(sh, "spec"):                    # NamedSharding inputs
        spec = tuple(sh.spec) + (None,) * (x.ndim - len(tuple(sh.spec)))
        assert spec[axis] == "hosp", f"{what}: spec {sh.spec} axis {axis}"


def check_placement(method, st, state):
    place = st.placement
    assert place.enabled and place.mesh.axis_names == ("hosp",)
    # the run batch stack, exactly as the run builders consume it
    from repro.core.strategies import engine as ENG
    data = [{"image": np.zeros((8, 8, 8, 1), np.float32),
             "label": np.zeros((8,), np.float32)}
            for _ in range(st.n_clients)]
    batches, _ = ENG.pack_run(data, BATCH, np.random.default_rng(0), 2,
                              pad_clients=place.n_pad)
    leaf = jax.tree.leaves(place.put(batches, axis=1))[0]
    assert_hosp_sharded(leaf, place.c_pad, 1, f"{method} run stack")
    # stacked client state persisted between rounds
    if "stacked_clients" in state:
        for l in jax.tree.leaves(state["stacked_clients"]):
            assert_hosp_sharded(l, place.c_pad, 0,
                                f"{method} stacked_clients")
    print(f"  {method}: sharding hosp x{jax.device_count()} "
          f"c_pad={place.c_pad} ok")


def compare(method, n_clients):
    clients, adapter = build(n_clients)
    st_a, sa, la, ta = run_once(method, clients, adapter, shard=False)
    st_b, sb, lb, tb = run_once(method, clients, adapter, shard=True)
    assert len(la) == len(lb) == EPOCHS
    for ea, eb in zip(la, lb):
        np.testing.assert_allclose(ea.losses, eb.losses, atol=ATOL)
        assert ea.client_steps == eb.client_steps
        assert ea.weights == eb.weights
    for i in range(n_clients):
        for a, b in zip(jax.tree.leaves(st_a.params_for_eval(sa, i)),
                        jax.tree.leaves(st_b.params_for_eval(sb, i))):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=ATOL)
    ra, rb = st_a.privacy_report(), st_b.privacy_report()
    assert len(ra) == len(rb)
    for x, y in zip(ra, rb):
        assert x["steps"] == y["steps"]
        assert abs(x["epsilon"] - y["epsilon"]) < 1e-9
    if ta is not None:
        assert (ta.steps, ta.bytes_on_wire) == (tb.steps, tb.bytes_on_wire)
        assert tb.bytes_on_wire > 0
    datas = [c.test for c in clients]
    for x, y in zip(st_a.scores_all(sa, datas, batch_size=BATCH),
                    st_b.scores_all(sb, datas, batch_size=BATCH)):
        np.testing.assert_allclose(x, y, atol=ATOL)
    check_placement(method, st_b, sb)
    print(f"  {method} n={n_clients}: parity ok "
          f"(eps={ra[0]['epsilon']:.3f})" if ra else
          f"  {method} n={n_clients}: parity ok")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--methods", default="fl,sl_am,sflv3_ac")
    ap.add_argument("--clients", default="5")
    args = ap.parse_args()
    if jax.device_count() < 2:
        print("NEED MULTIPLE DEVICES (set XLA_FLAGS)", file=sys.stderr)
        sys.exit(1)
    print(f"devices: {jax.device_count()}")
    for n in (int(x) for x in args.clients.split(",")):
        for method in args.methods.split(","):
            compare(method, n)
    print("PLACEMENT_OK")


if __name__ == "__main__":
    main()
