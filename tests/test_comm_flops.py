"""Invariants of the communication / computation accounting (paper §4.3/4.4)."""

import numpy as np
import pytest

from repro.core.comm import comm_per_epoch
from repro.core.flops import flops_per_epoch, segment_fwd_flops
from repro.core.partition import cnn_adapter
from repro.models.cnn import DenseNetConfig, build_densenet

# thin-client regime like the paper's "first 4 of 121 layers" split
CFG = DenseNetConfig(growth=8, blocks=(3, 6), stem_ch=8, cut_layer=1)
N_TRAIN = [48, 32, 48, 16, 32]
N_VAL = [16] * 5
BS = 8


def _adapter(nls=False):
    return cnn_adapter(build_densenet(CFG, nls=nls))


def _batch():
    return {"image": np.zeros((BS, 16, 16, 1), np.float32),
            "label": np.zeros((BS,), np.float32)}


def test_centralized_no_comm():
    c = comm_per_epoch("centralized", _adapter(), _batch(), N_TRAIN, N_VAL, BS)
    assert c.bytes_per_epoch == 0


def test_fl_comm_is_model_roundtrip():
    ad = _adapter()
    c = comm_per_epoch("fl", ad, _batch(), N_TRAIN, N_VAL, BS)
    import jax
    from repro.core.partition import leaf_bytes
    model_bytes = leaf_bytes(jax.eval_shape(ad.init, jax.random.key(0)))
    assert c.bytes_per_epoch == 2 * model_bytes * len(N_TRAIN)


def test_sl_comm_scales_with_batches():
    ad = _adapter()
    c1 = comm_per_epoch("sl_ac", ad, _batch(), N_TRAIN, N_VAL, BS)
    c2 = comm_per_epoch("sl_ac", ad, _batch(), [2 * n for n in N_TRAIN],
                        N_VAL, BS)
    assert c2.breakdown["train_act_up"] == 2 * c1.breakdown["train_act_up"]


def test_nls_comm_exceeds_ls():
    ls = comm_per_epoch("sl_ac", _adapter(False), _batch(), N_TRAIN, N_VAL, BS)
    nls = comm_per_epoch("sl_ac", _adapter(True), _batch(), N_TRAIN, N_VAL, BS)
    assert nls.bytes_per_epoch > ls.bytes_per_epoch


def test_sflv2_adds_client_averaging_traffic():
    sl = comm_per_epoch("sl_ac", _adapter(), _batch(), N_TRAIN, N_VAL, BS)
    v2 = comm_per_epoch("sflv2_ac", _adapter(), _batch(), N_TRAIN, N_VAL, BS)
    v3 = comm_per_epoch("sflv3_ac", _adapter(), _batch(), N_TRAIN, N_VAL, BS)
    assert v2.bytes_per_epoch > sl.bytes_per_epoch
    assert v3.bytes_per_epoch == sl.bytes_per_epoch   # server avg is local


def test_am_equals_ac_comm():
    """Paper Table 4: AC and AM move identical bytes."""
    ac = comm_per_epoch("sl_ac", _adapter(), _batch(), N_TRAIN, N_VAL, BS)
    am = comm_per_epoch("sl_am", _adapter(), _batch(), N_TRAIN, N_VAL, BS)
    assert ac.bytes_per_epoch == am.bytes_per_epoch


@pytest.fixture(scope="module")
def seg_fwd():
    return segment_fwd_flops(_adapter(), _batch())


def test_flops_split_partitions_centralized(seg_fwd):
    ad = _adapter()
    cen = flops_per_epoch("centralized", ad, _batch(), N_TRAIN, BS,
                          seg_fwd=seg_fwd)
    sl = flops_per_epoch("sl_ac", ad, _batch(), N_TRAIN, BS, seg_fwd=seg_fwd)
    # server + n_clients * avg_client == centralized total (same compute)
    total_split = sl.server_tflops + sl.avg_client_tflops * len(N_TRAIN)
    assert abs(total_split - cen.server_tflops) / cen.server_tflops < 1e-6


def test_fl_client_flops_exceed_sl_client_flops(seg_fwd):
    """Paper Tables 5/6: FL clients do the full model's work."""
    ad = _adapter()
    fl = flops_per_epoch("fl", ad, _batch(), N_TRAIN, BS, seg_fwd=seg_fwd)
    sl = flops_per_epoch("sl_ac", ad, _batch(), N_TRAIN, BS, seg_fwd=seg_fwd)
    assert fl.avg_client_tflops > 4 * sl.avg_client_tflops
    assert sl.server_tflops > sl.avg_client_tflops


def test_averaging_flops_ordering(seg_fwd):
    """FL/SFLv3 average model-sized segments; SFLv2 averages tiny clients."""
    ad = _adapter()
    fl = flops_per_epoch("fl", ad, _batch(), N_TRAIN, BS, seg_fwd=seg_fwd)
    v2 = flops_per_epoch("sflv2_ac", ad, _batch(), N_TRAIN, BS,
                         seg_fwd=seg_fwd)
    v3 = flops_per_epoch("sflv3_ac", ad, _batch(), N_TRAIN, BS,
                         seg_fwd=seg_fwd)
    assert v3.averaging_mflops > v2.averaging_mflops
    assert fl.averaging_mflops > v2.averaging_mflops
