"""Placement subsystem tests.

Two layers:

* in-process (single device): ``Placement`` construction, the
  pad-to-mesh phantom-hospital contract (``pack_epoch(pad_clients=...)``),
  and padded-client PARITY — a strategy whose placement is force-padded
  (mesh-less) must match the unpadded run on params, losses, accountant
  epsilon, and wire bytes, because phantom hospitals are zero-weight
  no-ops everywhere.
* subprocess (8 virtual host devices via ``XLA_FLAGS``): shard=True vs
  shard=False end-to-end parity for the whole-run programs, plus
  sharding inspection that the hospital axis really lands on the "hosp"
  mesh (tests/placement_driver.py).
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro import optim as O
from repro.core.partition import cnn_adapter
from repro.core.placement import Placement
from repro.core.strategies import make_strategy
from repro.core.strategies.engine import pack_epoch
from repro.data.synthetic import make_cxr_clients
from repro.models.cnn import DenseNetConfig, build_densenet
from repro.privacy import PrivacyConfig

DP = PrivacyConfig(noise_multiplier=1.1, clip_norm=1.0)


@pytest.fixture(scope="module")
def tiny_setup():
    clients = make_cxr_clients(seed=0, train_per_client=[17, 12, 9],
                               val_per_client=6, test_per_client=7,
                               image_size=16, n_clients=3)
    cfg = DenseNetConfig(growth=4, blocks=(1, 1), stem_ch=8, cut_layer=1)
    return clients, cnn_adapter(build_densenet(cfg))


# ---------------------------------------------------------------------------
# Placement construction
# ---------------------------------------------------------------------------

def test_make_single_device_is_noop():
    p = Placement.make(5, enabled=True, devices=[object()])
    assert not p.enabled and not p.padded
    assert p.c_pad == 5 and p.n_pad == 0
    # put/pad are identities
    x = np.ones((5, 3))
    assert p.put(x) is x
    assert p.pad_tree({"a": x})["a"] is x


def test_make_disabled():
    assert not Placement.make(5, enabled=False).enabled


def test_make_pads_to_device_multiple():
    devs = [object()] * 4
    p = Placement.make(5, enabled=True, devices=devs)
    assert p.enabled and p.c_pad == 8 and p.n_pad == 3
    np.testing.assert_array_equal(p.client_weights(),
                                  [1, 1, 1, 1, 1, 0, 0, 0])
    q = Placement.make(8, enabled=True, devices=devs)
    assert q.c_pad == 8 and not q.padded
    # fewer hospitals than devices: pad up to one row per device
    r = Placement.make(3, enabled=True, devices=devs)
    assert r.c_pad == 4 and r.n_pad == 1


def test_pad_tree_modes():
    p = Placement(n_clients=3, c_pad=5, mesh=None)
    t = {"w": np.arange(6, dtype=np.float32).reshape(3, 2)}
    edge = p.pad_tree(t)
    assert edge["w"].shape == (5, 2)
    np.testing.assert_array_equal(edge["w"][3], edge["w"][2])
    zeros = p.pad_tree(t, mode="zeros")
    np.testing.assert_array_equal(np.asarray(zeros["w"][3:]),
                                  np.zeros((2, 2)))
    # non-hospital leaves pass through untouched
    assert p.pad_tree({"s": np.ones((7,))})["s"].shape == (7,)


# ---------------------------------------------------------------------------
# phantom hospitals in pack_epoch
# ---------------------------------------------------------------------------

def test_pack_epoch_pad_clients():
    data = [{"x": np.arange(10, dtype=np.float32)[:, None],
             "label": np.arange(10)},
            {"x": np.arange(5, dtype=np.float32)[:, None],
             "label": np.arange(5)}]
    plain = pack_epoch(data, 2, np.random.default_rng(3))
    padded = pack_epoch(data, 2, np.random.default_rng(3), pad_clients=2)
    assert padded.mask.shape == (4, 5)
    assert padded.n_batches == [5, 2, 0, 0]
    assert padded.n_samples == [10, 5, 0, 0]
    assert padded.step_examples[2:] == [[], []]
    assert not padded.mask[2:].any()
    # real rows identical (same rng stream), phantom rows all-zero
    np.testing.assert_array_equal(padded.batches["x"][:2],
                                  plain.batches["x"])
    assert not padded.batches["x"][2:].any()
    assert padded.total_steps == plain.total_steps


# ---------------------------------------------------------------------------
# padded-client parity (single device, force-padded placement)
# ---------------------------------------------------------------------------

def _run_padded(method, clients, adapter, c_pad, privacy=None, epochs=2,
                transport=None, whole_run=False):
    st = make_strategy(method, adapter, lambda: O.adam(1e-3), len(clients),
                       privacy=privacy, transport=transport)
    if c_pad is not None:
        # force the padding contract without a mesh: every array carries
        # phantom hospital rows, placement itself stays single-device
        st.placement = Placement(len(clients), c_pad, None)
    state = st.setup(jax.random.key(0))
    rng = np.random.default_rng(0)
    data = [c.train for c in clients]
    if whole_run:
        state, logs = st.run(state, data, rng, 4, epochs)
        log = logs[-1]
    else:
        log = None
        for _ in range(epochs):
            state, log = st.run_epoch(state, data, rng, 4)
    return st, state, log


def _assert_padded_invariance(method, clients, adapter, privacy=None,
                              transport_pair=(None, None),
                              whole_run=False, atol=1e-5):
    ta, tb = transport_pair
    st_a, sa, la = _run_padded(method, clients, adapter, None, privacy,
                               transport=ta, whole_run=whole_run)
    st_b, sb, lb = _run_padded(method, clients, adapter,
                               len(clients) + 2, privacy,
                               transport=tb, whole_run=whole_run)
    np.testing.assert_allclose(la.losses, lb.losses, atol=atol)
    assert la.client_steps == lb.client_steps
    assert la.weights == lb.weights
    for i in range(len(clients)):
        for a, b in zip(jax.tree.leaves(st_a.params_for_eval(sa, i)),
                        jax.tree.leaves(st_b.params_for_eval(sb, i))):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=atol)
    ra, rb = st_a.privacy_report(), st_b.privacy_report()
    assert len(ra) == len(rb)
    for x, y in zip(ra, rb):
        assert x["steps"] == y["steps"]
        assert abs(x["epsilon"] - y["epsilon"]) < 1e-9
    if ta is not None:
        assert (ta.steps, ta.bytes_on_wire) == (tb.steps, tb.bytes_on_wire)


@pytest.mark.parametrize("method", ["fl", "sflv2_ac", "sflv3_ac"])
def test_phantom_hospital_invariance(method, tiny_setup):
    """Adding zero-weight phantom hospitals changes nothing: params,
    losses, logs — the padding contract the mesh placement relies on."""
    clients, adapter = tiny_setup
    _assert_padded_invariance(method, clients, adapter)


@pytest.mark.parametrize("method", ["fl", "sflv3_ac"])
def test_phantom_invariance_dp(method, tiny_setup):
    """Phantom hospitals draw no DP noise for real hospitals (fold_in
    per-client/per-example keys) and compose no accountant steps."""
    clients, adapter = tiny_setup
    _assert_padded_invariance(method, clients, adapter, privacy=DP)


def test_phantom_invariance_wire_bytes(tiny_setup):
    """Wire byte meters and recorded epoch signatures ignore phantoms."""
    from repro.wire import Transport
    clients, adapter = tiny_setup
    ta, tb = Transport("identity"), Transport("identity")
    _assert_padded_invariance("sl_am", clients, adapter,
                              transport_pair=(ta, tb))
    assert ta.bytes_on_wire > 0


@pytest.mark.parametrize("method", ["fl", "sflv2_ac", "sflv3_ac"])
def test_phantom_invariance_whole_run(method, tiny_setup):
    """The [E, C, NB, ...] whole-run programs honor the same contract."""
    clients, adapter = tiny_setup
    _assert_padded_invariance(method, clients, adapter, whole_run=True)


def test_padded_eval_matches(tiny_setup):
    """scores_all with a padded hospital axis returns identical scores."""
    clients, adapter = tiny_setup
    st_a, sa, _ = _run_padded("sflv3_ac", clients, adapter, None, epochs=1)
    st_b, sb, _ = _run_padded("sflv3_ac", clients, adapter, 5, epochs=1)
    datas = [c.test for c in clients]
    for x, y in zip(st_a.scores_all(sa, datas, batch_size=4),
                    st_b.scores_all(sb, datas, batch_size=4)):
        np.testing.assert_allclose(x, y, atol=1e-5)


# ---------------------------------------------------------------------------
# real multi-device placement (8 virtual host devices, subprocess)
# ---------------------------------------------------------------------------

def _run_driver(args, timeout=1200):
    out = subprocess.run(
        [sys.executable, os.path.join(os.path.dirname(__file__),
                                      "placement_driver.py"), *args],
        capture_output=True, text=True, timeout=timeout,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")})
    assert "PLACEMENT_OK" in out.stdout, (out.stdout[-2000:]
                                          + out.stderr[-2000:])
    return out.stdout


def test_sharded_parity_padded_subprocess():
    """n_clients=5 on 8 virtual devices (3 phantoms): shard=True matches
    shard=False ≤1e-5 (params/losses/eps/wire bytes) and the hospital
    axis is really placed on the "hosp" mesh."""
    out = _run_driver(["--methods", "fl,sl_am,sflv3_ac", "--clients", "5"])
    assert "sharding hosp" in out


@pytest.mark.slow
def test_sharded_parity_full_grid_subprocess():
    """Every method of the paper grid, even and padded hospital counts."""
    _run_driver(["--methods", "fl,sl_am,sflv2_ac,sflv3_ac,sflv1_ac",
                 "--clients", "8,5"], timeout=2400)
