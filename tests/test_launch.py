"""Launch-layer tests: sharding rules, cache specs, HLO analyzer, and a
subprocess 512-device mesh construction check."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_analysis import analyze


class FakeMesh:
    """Duck-typed mesh for spec_for tests (no 256 devices needed)."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.zeros(shape)


def test_spec_for_rules():
    from repro.launch.mesh import spec_for
    mesh = FakeMesh((16, 16), ("data", "model"))
    # ff -> model, embed -> data
    assert spec_for(("embed", "ff"), (1024, 4096), mesh) == P("data", "model")
    # indivisible vocab falls back to replicated (MiniCPM's 122753)
    assert spec_for(("embed", "vocab"), (2304, 122753), mesh) == P("data")
    # a mesh axis is never used twice in one spec
    assert spec_for(("ff", "ff"), (4096, 4096), mesh) == P("model")
    # clients axis consumes data; embed then falls to pod (absent) -> None
    assert spec_for(("clients", "embed", "ff"), (16, 1024, 4096), mesh) == \
        P("data", None, "model")


def test_spec_for_multipod_fsdp():
    from repro.launch.mesh import spec_for
    mesh = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    # embed prefers data; with clients on data it falls to pod
    assert spec_for(("clients", "embed"), (32, 7168), mesh) == P("data", "pod")


def test_analyze_counts_scan_iterations():
    def scan6(x, ws):
        def body(c, w):
            return jnp.dot(c, w), None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
    txt = jax.jit(scan6).lower(x, ws).compile().as_text()
    ana = analyze(txt)
    assert ana["flops"] == 6 * 2 * 64 ** 3
    # raw cost_analysis counts the body once — the analyzer must not
    ca = jax.jit(scan6).lower(x, ws).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):      # older jax returns [dict]
        ca = ca[0]
    assert ca["flops"] < ana["flops"]


def test_analyze_collectives_zero_on_single_device():
    txt = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile().as_text()
    ana = analyze(txt)
    assert ana["collective_total"] == 0


def test_production_mesh_subprocess():
    """make_production_mesh needs 512 host devices; run in a fresh process."""
    code = (
        "import os;"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=512';"
        "from repro.launch.mesh import make_production_mesh;"
        "m1=make_production_mesh();"
        "assert m1.devices.shape==(16,16) and m1.axis_names==('data','model');"
        "m2=make_production_mesh(multi_pod=True);"
        "assert m2.devices.shape==(2,16,16);"
        "assert m2.axis_names==('pod','data','model');"
        "print('MESH_OK')"
    )
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             # without this a TPU-plugin build polls cloud metadata forever
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
        timeout=120)
    assert "MESH_OK" in out.stdout, out.stderr[-500:]


def test_cache_specs_shard_decode_batch():
    from repro.launch.specs import cache_specs
    from repro.models.transformer import ModelConfig, TransformerLM
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
                      cut_layer=1, remat=False)
    model = TransformerLM.build(cfg)
    mesh = FakeMesh((16, 16), ("data", "model"))
    shapes, shardings = cache_specs(model, "decode_32k", mesh, as_pspec=True)
    flat = jax.tree.leaves(shardings)
    assert len(flat) > 0
    # k/v leaves: stacked layer dim unsharded, batch on data, seq on model
    from jax.sharding import PartitionSpec as PS
    leaves = jax.tree.leaves(shardings, is_leaf=lambda v: isinstance(v, PS))
    kv = [s for s, l in zip(leaves, jax.tree.leaves(shapes))
          if len(l.shape) == 5]
    assert all(s[1] == "data" for s in kv)
    assert all(s[2] == "model" for s in kv)


def test_cache_specs_long500k_unshardable_batch():
    from repro.launch.specs import cache_specs
    from repro.models.transformer import ModelConfig, TransformerLM
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
                      cut_layer=1, remat=False)
    model = TransformerLM.build(cfg)
    mesh = FakeMesh((16, 16), ("data", "model"))
    shapes, shardings = cache_specs(model, "long_500k", mesh, as_pspec=True)
    from jax.sharding import PartitionSpec as PS
    leaves = jax.tree.leaves(shardings, is_leaf=lambda v: isinstance(v, PS))
    kv = [s for s, l in zip(leaves, jax.tree.leaves(shapes))
          if len(l.shape) == 5]
    # batch==1: replicate batch, shard seq over every axis
    assert all(s[1] is None for s in kv)
    assert all(s[2] == ("data", "model") for s in kv)
