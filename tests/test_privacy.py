"""repro.privacy: DP-SGD kernel/equivalence, accountant, leakage, secagg."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim as O
from repro.core.partition import cnn_adapter
from repro.core.strategies import make_strategy
from repro.data.synthetic import make_cxr_clients
from repro.kernels.dp_clip.ops import clip_accumulate
from repro.kernels.dp_clip.ref import clip_accumulate_ref
from repro.models.cnn import DenseNetConfig, build_densenet
from repro.privacy import (PrivacyConfig, RDPAccountant, SecAgg,
                           distance_correlation, dp_value_and_grad,
                           epsilon, measure_leakage, reconstruction_probe)
from repro.privacy.dpsgd import keyed


# ---------------------------------------------------------------------------
# fused Pallas clip kernel vs reference (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shapes", [
    [(6, 8, 16)], [(3, 130), (3, 7)], [(16, 4, 4, 3), (16, 640), (16, 1)],
])
@pytest.mark.parametrize("clip", [0.05, 1.0, float("inf")])
def test_clip_kernel_matches_ref(shapes, clip):
    ks = jax.random.split(jax.random.key(0), len(shapes))
    tree = {f"l{i}": jax.random.normal(k, s) * 3
            for i, (k, s) in enumerate(zip(ks, shapes))}
    out, norms = clip_accumulate(tree, clip)
    ref, rnorms = clip_accumulate_ref(tree, clip)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(norms), np.asarray(rnorms),
                               rtol=1e-5)


def test_clip_kernel_bounds_every_example():
    """After clipping, every per-example contribution has norm <= C."""
    g = jax.random.normal(jax.random.key(1), (8, 257)) * 10
    clip = 0.5
    out, norms = clip_accumulate({"g": g}, clip)
    scales = np.minimum(1.0, clip / np.asarray(norms))
    manual = (np.asarray(g) * scales[:, None]).sum(axis=0)
    np.testing.assert_allclose(np.asarray(out["g"]), manual, atol=1e-5)
    per_ex = np.linalg.norm(np.asarray(g) * scales[:, None], axis=1)
    assert (per_ex <= clip + 1e-5).all()


def test_clip_kernel_zero_grads():
    out, norms = clip_accumulate({"g": jnp.zeros((4, 130))}, 1.0)
    assert np.asarray(norms).max() == 0.0
    assert np.isfinite(np.asarray(out["g"])).all()
    np.testing.assert_array_equal(np.asarray(out["g"]),
                                  np.zeros((130,), np.float32))


# ---------------------------------------------------------------------------
# dp_value_and_grad
# ---------------------------------------------------------------------------

def _quad_loss(params, batch):
    pred = batch["x"] @ params["w"]
    return jnp.mean((pred - batch["y"]) ** 2)


def test_dp_grad_neutral_equals_plain():
    """noise 0 + clip inf: DP estimator == batch gradient."""
    k = jax.random.key(0)
    params = {"w": jax.random.normal(k, (5,))}
    batch = {"x": jax.random.normal(jax.random.key(1), (12, 5)),
             "y": jax.random.normal(jax.random.key(2), (12,))}
    cfg = PrivacyConfig(noise_multiplier=0.0, clip_norm=float("inf"),
                        force_dp=True)
    loss_dp, g_dp = dp_value_and_grad(keyed(_quad_loss), cfg)(
        params, batch, jax.random.key(3))
    loss, g = jax.value_and_grad(_quad_loss)(params, batch)
    np.testing.assert_allclose(float(loss_dp), float(loss), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g_dp["w"]), np.asarray(g["w"]),
                               atol=1e-6)


def test_dp_grad_noise_scale():
    """With zero gradients the DP output IS the noise: std sigma*C/B."""
    params = {"w": jnp.zeros((2048,))}
    batch = {"x": jnp.zeros((4, 2048)), "y": jnp.zeros((4,))}
    sigma, clip = 2.0, 1.5
    cfg = PrivacyConfig(noise_multiplier=sigma, clip_norm=clip)
    _, g = dp_value_and_grad(keyed(_quad_loss), cfg)(
        params, batch, jax.random.key(0))
    want = sigma * clip / 4
    assert abs(float(jnp.std(g["w"])) - want) < 0.1 * want


def test_dp_grad_deterministic_per_key():
    params = {"w": jnp.ones((16,))}
    batch = {"x": jax.random.normal(jax.random.key(1), (4, 16)),
             "y": jnp.zeros((4,))}
    cfg = PrivacyConfig(noise_multiplier=1.0, clip_norm=1.0)
    vg = dp_value_and_grad(keyed(_quad_loss), cfg)
    _, g1 = vg(params, batch, jax.random.key(7))
    _, g2 = vg(params, batch, jax.random.key(7))
    _, g3 = vg(params, batch, jax.random.key(8))
    np.testing.assert_array_equal(np.asarray(g1["w"]), np.asarray(g2["w"]))
    assert np.abs(np.asarray(g1["w"]) - np.asarray(g3["w"])).max() > 0


# ---------------------------------------------------------------------------
# end-to-end: neutral DP training == non-private training (acceptance)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_setup():
    clients = make_cxr_clients(seed=0, train_per_client=24,
                               val_per_client=12, test_per_client=17,
                               image_size=16, n_clients=3)
    cfg = DenseNetConfig(growth=4, blocks=(1, 1), stem_ch=8, cut_layer=1)
    return clients, cnn_adapter(build_densenet(cfg))


def _train(method, clients, adapter, privacy, epochs=1, batch=8):
    st = make_strategy(method, adapter, lambda: O.adam(1e-3),
                       len(clients), privacy=privacy)
    state = st.setup(jax.random.key(0))
    rng = np.random.default_rng(0)
    log = None
    for _ in range(epochs):
        state, log = st.run_epoch(state, [c.train for c in clients], rng,
                                  batch)
    return st, state, log


@pytest.mark.parametrize("method", ["fl", "sl_ac", "sflv3_ac"])
def test_neutral_dp_equals_nonprivate(method, tiny_setup):
    clients, adapter = tiny_setup
    neutral = PrivacyConfig(noise_multiplier=0.0, clip_norm=float("inf"),
                            force_dp=True)
    _, s_plain, log_plain = _train(method, clients, adapter, None)
    _, s_dp, log_dp = _train(method, clients, adapter, neutral)
    assert abs(log_plain.mean_loss - log_dp.mean_loss) < 1e-5
    st = make_strategy(method, adapter, lambda: O.adam(1e-3),
                       len(clients))
    for i in range(len(clients)):
        pa = st.params_for_eval(s_plain, i)
        pb = st.params_for_eval(s_dp, i)
        for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=1e-5)


@pytest.mark.slow
@pytest.mark.parametrize("method", ["fl", "sl_ac", "sflv2_ac", "sflv3_ac"])
def test_private_training_runs_and_accounts(method, tiny_setup):
    clients, adapter = tiny_setup
    priv = PrivacyConfig(noise_multiplier=1.0, clip_norm=1.0,
                         secagg=method == "fl")
    st, state, log = _train(method, clients, adapter, priv)
    assert np.isfinite(log.mean_loss)
    report = st.privacy_report()
    assert len(report) == len(clients)
    for r in report:
        assert 0 < r["epsilon"] < 50 and r["steps"] > 0
    m = st.evaluate(state, clients, "test", batch_size=16)
    assert 0.0 <= m["auroc"] <= 1.0


def test_cut_noise_trains_and_reduces_leakage(tiny_setup):
    clients, adapter = tiny_setup
    st, state, log = _train(
        "sl_ac", clients, adapter, PrivacyConfig(cut_noise_std=2.0))
    assert np.isfinite(log.mean_loss)
    params = st.params_for_eval(state, 0)
    batch = {k: v[:16] for k, v in clients[0].test.items()}
    clean = measure_leakage(adapter, params, batch)
    noised = measure_leakage(adapter, params, batch,
                             privacy=PrivacyConfig(cut_noise_std=25.0))
    assert noised["dcor_input"] < clean["dcor_input"]
    assert noised["probe"]["r2"] <= clean["probe"]["r2"] + 1e-9


def test_scores_partial_batch_not_dropped(tiny_setup):
    """17 test samples, batch 16: every sample must be scored."""
    clients, adapter = tiny_setup
    st, state, _ = _train("fl", clients, adapter, None)
    s = st.scores(state, 0, clients[0].test, batch_size=16)
    assert len(s) == len(clients[0].test["label"]) == 17
    # identical to scoring sample-by-sample with one full batch
    whole = st.scores(state, 0, clients[0].test, batch_size=17)
    np.testing.assert_allclose(s, whole, atol=1e-6)


def test_noise_without_clip_rejected():
    """sigma > 0 with clip inf has unbounded sensitivity: no valid eps."""
    with pytest.raises(ValueError):
        PrivacyConfig(noise_multiplier=1.0)        # default clip_norm=inf


def test_centralized_dp_accounts_every_hospital(tiny_setup):
    clients, adapter = tiny_setup
    st, _, _ = _train("centralized", clients, adapter,
                      PrivacyConfig(noise_multiplier=1.0, clip_norm=1.0))
    report = st.privacy_report()
    assert len(report) == len(clients)
    assert all(0 < r["epsilon"] < 50 for r in report)
    assert len({round(r["epsilon"], 9) for r in report}) == 1  # pooled q


def test_make_strategy_privacy_validation(tiny_setup):
    clients, adapter = tiny_setup
    with pytest.raises(ValueError):
        make_strategy("fl", adapter, lambda: O.adam(1e-3), 3,
                      privacy=PrivacyConfig(cut_noise_std=1.0))
    with pytest.raises(ValueError):
        make_strategy("sl_ac", adapter, lambda: O.adam(1e-3), 3,
                      privacy=PrivacyConfig(secagg=True))


# ---------------------------------------------------------------------------
# accountant
# ---------------------------------------------------------------------------

def test_accountant_known_regime():
    eps = epsilon(1.0, q=0.01, steps=1000, delta=1e-5)
    assert 1.0 < eps < 4.0            # integer-order RDP, classic conversion


def test_accountant_edge_cases():
    assert math.isinf(epsilon(0.0, 0.1, 10))        # no noise, no guarantee
    assert epsilon(1.0, 0.0, 10) == 0.0             # never sampled
    full = epsilon(2.0, 1.0, 1, delta=1e-5)         # q=1: plain Gaussian
    assert 0 < full < math.inf


def test_accountant_monotone():
    e1 = epsilon(1.0, 0.05, 100)
    assert epsilon(1.0, 0.05, 200) > e1             # more steps, worse
    assert epsilon(2.0, 0.05, 100) < e1             # more noise, better
    assert epsilon(1.0, 0.10, 100) > e1             # more sampling, worse


def test_accountant_composition_additive():
    a = RDPAccountant(1.0, 1e-5)
    a.step(0.02, 50)
    b = RDPAccountant(1.0, 1e-5)
    for _ in range(50):
        b.step(0.02, 1)
    np.testing.assert_allclose(a.epsilon()[0], b.epsilon()[0], rtol=1e-12)


# ---------------------------------------------------------------------------
# leakage metrics
# ---------------------------------------------------------------------------

def test_dcor_dependence_and_independence():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(400, 4))
    z = rng.normal(size=(400, 4))
    lin = distance_correlation(x, x * 2.0 + 1.0)
    sq = distance_correlation(x, x ** 2)          # nonlinear dependence
    indep = distance_correlation(x, z)            # finite-sample bias ~0.18
    assert lin > 0.99
    assert sq > 0.3
    assert indep < 0.25
    assert indep < sq < lin


def test_dcor_constant_input():
    x = np.ones((32, 3))
    assert distance_correlation(x, np.random.default_rng(0)
                                .normal(size=(32, 2))) == 0.0


def test_reconstruction_probe_recovers_linear_map():
    rng = np.random.default_rng(1)
    inputs = rng.normal(size=(256, 6))
    acts = inputs @ rng.normal(size=(6, 12)) + 0.01 * rng.normal(
        size=(256, 12))
    probe = reconstruction_probe(acts, inputs)
    assert probe["r2"] > 0.95
    noise_probe = reconstruction_probe(rng.normal(size=(256, 12)), inputs)
    assert noise_probe["r2"] < 0.2


# ---------------------------------------------------------------------------
# secure aggregation
# ---------------------------------------------------------------------------

def test_secagg_exact_aggregate():
    rng = np.random.default_rng(2)
    n = 5
    trees = [{"w": rng.normal(size=(16, 8)).astype(np.float32),
              "b": rng.normal(size=(8,)).astype(np.float32)}
             for _ in range(n)]
    weights = [5, 1, 3, 2, 4]
    sa = SecAgg(n, seed=3)
    agg = sa.aggregate_weighted(trees, weights)
    for k in ("w", "b"):
        ref = sum(w * t[k] for w, t in zip(weights, trees)) / sum(weights)
        np.testing.assert_allclose(agg[k], ref, atol=2 ** -14)


def test_secagg_masked_upload_is_garbage():
    """A single masked upload must not resemble the raw update."""
    rng = np.random.default_rng(4)
    tree = {"w": rng.normal(size=(64, 16)).astype(np.float32)}
    sa = SecAgg(3, seed=5)
    masked = sa.mask_update(0, tree, 1.0 / 3)
    u = masked["w"].astype(np.float64) / 2 ** 32
    assert 0.15 < u.std() < 0.35          # ~uniform on [0, 1)
    assert abs(np.corrcoef(u.ravel(), tree["w"].ravel())[0, 1]) < 0.1


def test_secagg_meters_bytes():
    trees = [{"w": np.zeros((32, 4), np.float32)} for _ in range(4)]
    sa = SecAgg(4, seed=0)
    sa.aggregate_weighted(trees, [1, 1, 1, 1])
    payload = 4 * 32 * 4 * 4              # clients x elements x 4 bytes
    assert sa.bytes_on_wire == payload + sa.handshake_bytes()
    assert sa.handshake_bytes() > 0


def test_fedavg_secagg_matches_plain_average(tiny_setup):
    clients, adapter = tiny_setup
    _, plain, _ = _train("fl", clients, adapter, None)
    _, masked, _ = _train("fl", clients, adapter,
                          PrivacyConfig(secagg=True))
    for a, b in zip(jax.tree.leaves(plain["params"]),
                    jax.tree.leaves(masked["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2 ** -12)


# ---------------------------------------------------------------------------
# optim: clip_by_global_norm + chain + the DP noise step (satellite)
# ---------------------------------------------------------------------------

def test_clip_by_global_norm_scales_exactly():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((3, 4), -2.0)}
    norm = float(jnp.sqrt(sum(jnp.sum(x * x)
                              for x in jax.tree.leaves(g))))
    opt = O.clip_by_global_norm(1.0)
    out, _ = opt.update(g, opt.init(g))
    for orig, clipped in zip(jax.tree.leaves(g), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(clipped),
                                   np.asarray(orig) / norm, rtol=1e-6)
    big, _ = O.clip_by_global_norm(norm * 10).update(g, {})
    for orig, kept in zip(jax.tree.leaves(g), jax.tree.leaves(big)):
        np.testing.assert_allclose(np.asarray(kept), np.asarray(orig))


def test_clip_by_global_norm_zero_grads():
    g = {"a": jnp.zeros((8,))}
    out, _ = O.clip_by_global_norm(1.0).update(g, {})
    assert np.isfinite(np.asarray(out["a"])).all()
    np.testing.assert_array_equal(np.asarray(out["a"]), np.zeros(8))


def test_chain_clip_then_noise_ordering():
    """clip->noise: the noise rides on TOP of the clipped gradient (DP
    ordering); noise->clip: the final update is norm-bounded instead."""
    g = {"w": jnp.full((512,), 100.0)}
    clip_noise = O.chain(O.clip_by_global_norm(1.0), O.add_noise(0.5,
                                                                 seed=1))
    noise_clip = O.chain(O.add_noise(0.5, seed=1),
                         O.clip_by_global_norm(1.0))
    u1, _ = clip_noise.update(g, clip_noise.init(g))
    u2, _ = noise_clip.update(g, noise_clip.init(g))
    n1 = float(jnp.linalg.norm(u1["w"]))
    n2 = float(jnp.linalg.norm(u2["w"]))
    assert n2 <= 1.0 + 1e-5               # clipped last => bounded
    assert n1 > 1.0                       # noise after clip => unbounded
    clipped, _ = O.clip_by_global_norm(1.0).update(g, {})
    resid = np.asarray(u1["w"]) - np.asarray(clipped["w"])
    assert abs(resid.std() - 0.5) < 0.1   # the ride-along noise


def test_add_noise_zero_std_is_identity_and_key_advances():
    g = {"w": jnp.arange(8.0)}
    opt = O.add_noise(0.0)
    state = opt.init(g)
    out, state2 = opt.update(g, state)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(g["w"]))
    noisy = O.add_noise(1.0, seed=0)
    s = noisy.init(g)
    a, s = noisy.update(g, s)
    b, s = noisy.update(g, s)
    assert np.abs(np.asarray(a["w"]) - np.asarray(b["w"])).max() > 0


def test_chain_with_bf16_adam_state():
    g = {"w": jnp.ones((16,), jnp.float32)}
    p = {"w": jnp.zeros((16,), jnp.float32)}
    opt = O.chain(O.clip_by_global_norm(1.0), O.add_noise(0.1, seed=2),
                  O.adam(1e-3, state_dtype=jnp.bfloat16))
    state = opt.init(p)
    up, state = opt.update(g, state, p)
    assert state[2]["mu"]["w"].dtype == jnp.bfloat16
    newp = O.apply_updates(p, up)
    assert np.isfinite(np.asarray(newp["w"])).all()
    assert np.abs(np.asarray(newp["w"])).max() > 0
