"""Metrics cross-checks: rank AUROC / grouped AUPRC vs brute-force O(n^2)
and trapezoid references, tie-heavy and degenerate inputs included; the new
sensitivity / specificity / ECE satellites."""

import numpy as np
import pytest

from repro.train.metrics import (all_metrics, auprc, auroc, confusion,
                                 expected_calibration_error, sensitivity,
                                 specificity)


# ---------------------------------------------------------------------------
# brute-force references
# ---------------------------------------------------------------------------

def auroc_bruteforce(labels, scores):
    """O(n^2) Mann-Whitney: P(s_pos > s_neg) + 0.5 P(s_pos == s_neg)."""
    labels = np.asarray(labels).astype(bool).ravel()
    scores = np.asarray(scores, np.float64).ravel()
    pos, neg = scores[labels], scores[~labels]
    if len(pos) == 0 or len(neg) == 0:
        return float("nan")
    wins = (pos[:, None] > neg[None, :]).sum()
    ties = (pos[:, None] == neg[None, :]).sum()
    return float((wins + 0.5 * ties) / (len(pos) * len(neg)))


def auprc_bruteforce(labels, scores):
    """Step-integrated AP over DISTINCT thresholds (tie-grouped)."""
    labels = np.asarray(labels).astype(bool).ravel()
    scores = np.asarray(scores, np.float64).ravel()
    n_pos = labels.sum()
    if n_pos == 0:
        return float("nan")
    ap, prev_tp = 0.0, 0
    for t in sorted(set(scores), reverse=True):
        sel = scores >= t
        tp = int((labels & sel).sum())
        precision = tp / int(sel.sum())
        ap += precision * (tp - prev_tp) / n_pos
        prev_tp = tp
    return float(ap)


def _cases(seed):
    rng = np.random.default_rng(seed)
    n = rng.integers(5, 60)
    labels = rng.integers(0, 2, n).astype(float)
    kind = seed % 3
    if kind == 0:
        scores = rng.uniform(0, 1, n)                      # continuous
    elif kind == 1:
        scores = rng.choice([0.1, 0.5, 0.9], n)            # tie-heavy
    else:
        scores = np.round(rng.uniform(0, 1, n), 1)         # quantized ties
    return labels, scores


@pytest.mark.parametrize("seed", range(12))
def test_auroc_matches_bruteforce(seed):
    labels, scores = _cases(seed)
    if labels.sum() in (0, len(labels)):
        labels[0], labels[-1] = 1.0, 0.0
    np.testing.assert_allclose(auroc(labels, scores),
                               auroc_bruteforce(labels, scores),
                               atol=1e-12)


@pytest.mark.parametrize("seed", range(12))
def test_auprc_matches_bruteforce(seed):
    labels, scores = _cases(seed)
    if labels.sum() == 0:
        labels[0] = 1.0
    np.testing.assert_allclose(auprc(labels, scores),
                               auprc_bruteforce(labels, scores),
                               atol=1e-12)


def test_auprc_tie_order_invariant():
    """Tied scores must yield the same AP whatever the input order."""
    labels = np.array([1, 0, 1, 0, 0, 1])
    scores = np.array([0.5, 0.5, 0.5, 0.2, 0.2, 0.2])
    base = auprc(labels, scores)
    rng = np.random.default_rng(0)
    for _ in range(5):
        perm = rng.permutation(len(labels))
        np.testing.assert_allclose(auprc(labels[perm], scores[perm]), base,
                                   atol=1e-12)


def test_all_ties_collapse_to_prevalence():
    labels = np.array([1, 1, 0, 0, 0, 0, 0, 0])
    scores = np.full(8, 0.7)
    assert auroc(labels, scores) == 0.5
    np.testing.assert_allclose(auprc(labels, scores), 0.25)  # prevalence


def test_degenerate_single_class():
    scores = np.linspace(0, 1, 10)
    assert np.isnan(auroc(np.zeros(10), scores))
    assert np.isnan(auroc(np.ones(10), scores))
    assert np.isnan(auprc(np.zeros(10), scores))
    assert auprc(np.ones(10), scores) == 1.0
    assert np.isnan(auroc_bruteforce(np.zeros(10), scores))


def test_perfect_and_inverted_ranking():
    labels = np.array([0, 0, 0, 1, 1])
    scores = np.array([0.1, 0.2, 0.3, 0.8, 0.9])
    assert auroc(labels, scores) == 1.0
    assert auprc(labels, scores) == 1.0
    assert auroc(labels, 1 - scores) == 0.0


# ---------------------------------------------------------------------------
# sensitivity / specificity / ECE
# ---------------------------------------------------------------------------

def test_sensitivity_specificity_from_confusion():
    labels = np.array([1, 1, 1, 0, 0, 0, 0, 1])
    scores = np.array([0.9, 0.8, 0.2, 0.1, 0.7, 0.3, 0.4, 0.6])
    tp, fp, fn, tn = confusion(labels, scores)
    assert (tp, fp, fn, tn) == (3, 1, 1, 3)
    assert sensitivity(labels, scores) == 0.75
    assert specificity(labels, scores) == 0.75


def test_sensitivity_specificity_degenerate():
    assert np.isnan(sensitivity(np.zeros(4), np.linspace(0, 1, 4)))
    assert np.isnan(specificity(np.ones(4), np.linspace(0, 1, 4)))


def test_ece_perfectly_calibrated_bins():
    # within each bin, score == empirical frequency -> ECE == 0
    labels = np.array([1, 0, 1, 0] * 25)
    scores = np.full(100, 0.5)
    np.testing.assert_allclose(expected_calibration_error(labels, scores),
                               0.0, atol=1e-12)


def test_ece_maximally_miscalibrated():
    labels = np.zeros(50)
    scores = np.full(50, 0.95)          # confident and always wrong
    np.testing.assert_allclose(expected_calibration_error(labels, scores),
                               0.95, atol=1e-12)


def test_ece_empty_and_bounds():
    assert np.isnan(expected_calibration_error(np.array([]),
                                               np.array([])))
    rng = np.random.default_rng(1)
    labels = rng.integers(0, 2, 200)
    scores = rng.uniform(0, 1, 200)
    assert 0.0 <= expected_calibration_error(labels, scores) <= 1.0


def test_all_metrics_has_new_keys():
    labels = np.array([1, 0, 1, 0, 1])
    scores = np.array([0.9, 0.2, 0.7, 0.4, 0.3])
    m = all_metrics(labels, scores)
    for k in ("auroc", "auprc", "f1", "kappa", "sensitivity",
              "specificity", "ece"):
        assert k in m
