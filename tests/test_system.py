"""End-to-end behaviour tests for the distributed-learning system."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim as O
from repro.core.partition import cnn_adapter, lm_adapter
from repro.core.strategies import make_strategy
from repro.data.synthetic import make_cxr_clients
from repro.models.cnn import DenseNetConfig, build_densenet


@pytest.fixture(scope="module")
def tiny_setup():
    clients = make_cxr_clients(seed=0, train_per_client=32,
                               val_per_client=16, test_per_client=16,
                               image_size=16)
    cfg = DenseNetConfig(growth=4, blocks=(1, 1), stem_ch=8, cut_layer=1)
    return clients, cfg


def _run(method, nls, clients, cfg, epochs=1):
    ad = cnn_adapter(build_densenet(cfg, nls=nls))
    st = make_strategy(method, ad, lambda: O.adam(1e-3), len(clients))
    state = st.setup(jax.random.key(0))
    rng = np.random.default_rng(0)
    for _ in range(epochs):
        state, log = st.run_epoch(state, [c.train for c in clients], rng, 8)
    return st, state, log


@pytest.mark.parametrize("method", ["centralized", "fl", "sl_ac", "sl_am",
                                    "sflv2_ac", "sflv3_ac", "sflv1_ac"])
@pytest.mark.parametrize("nls", [False, True])
def test_method_runs_and_evaluates(method, nls, tiny_setup):
    clients, cfg = tiny_setup
    if method in ("centralized", "fl") and nls:
        pytest.skip("nls split irrelevant for non-split methods")
    st, state, log = _run(method, nls, clients, cfg)
    assert np.isfinite(log.mean_loss)
    m = st.evaluate(state, clients, "test", batch_size=16)
    assert 0.0 <= m["auroc"] <= 1.0


def _client_trees(state, first_last=(0, -1)):
    """Client segment trees under either engine layout (stepwise keeps a
    list, the default compiled engine keeps the hospital axis stacked)."""
    if "stacked_clients" in state:
        from repro.core.partition import tree_take
        return [tree_take(state["stacked_clients"], i) for i in first_last]
    return [state["clients"][i] for i in first_last]


def test_sflv2_synchronizes_clients(tiny_setup):
    clients, cfg = tiny_setup
    st, state, _ = _run("sflv2_ac", False, clients, cfg)
    c0, c1 = _client_trees(state)
    for a, b in zip(jax.tree.leaves(c0), jax.tree.leaves(c1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sl_keeps_clients_unique(tiny_setup):
    clients, cfg = tiny_setup
    st, state, _ = _run("sl_ac", False, clients, cfg)
    c0, c1 = _client_trees(state)
    diffs = [float(jnp.abs(a - b).max())
             for a, b in zip(jax.tree.leaves(c0), jax.tree.leaves(c1))]
    assert max(diffs) > 0


def test_sflv3_keeps_clients_unique_and_one_server(tiny_setup):
    clients, cfg = tiny_setup
    st, state, _ = _run("sflv3_ac", False, clients, cfg)
    sc = state["stacked_clients"]
    leaf = jax.tree.leaves(sc)[0]
    assert leaf.shape[0] == len(clients)
    # at least one client pair differs
    assert any(float(jnp.abs(l[0] - l[-1]).max()) > 0
               for l in jax.tree.leaves(sc))


def test_sflv1_averages_clients(tiny_setup):
    clients, cfg = tiny_setup
    st, state, _ = _run("sflv1_ac", False, clients, cfg)
    for l in jax.tree.leaves(state["stacked_clients"]):
        np.testing.assert_allclose(np.asarray(l[0]), np.asarray(l[-1]),
                                   rtol=1e-6, atol=1e-7)


def test_fedavg_single_client_identity(tiny_setup):
    """FedAvg with one client == that client's local training."""
    clients, cfg = tiny_setup
    ad = cnn_adapter(build_densenet(cfg))
    st = make_strategy("fl", ad, lambda: O.adam(1e-3), 1)
    state = st.setup(jax.random.key(0))
    p0 = state["params"]
    state, _ = st.run_epoch(state, [clients[0].train],
                            np.random.default_rng(0), 8)

    from repro.core.strategies.base import make_full_step, np_batches
    opt = O.adam(1e-3)
    step = make_full_step(ad, opt)
    p, s = p0, opt.init(p0)
    for b in np_batches(clients[0].train, 8, np.random.default_rng(0)):
        p, s, _ = step(p, s, b)
    for a, b in zip(jax.tree.leaves(state["params"]), jax.tree.leaves(p)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_split_equals_full_forward(tiny_setup):
    """front o middle (o tail) must equal the unsplit forward."""
    clients, cfg = tiny_setup
    for nls in (False, True):
        ad = cnn_adapter(build_densenet(cfg, nls=nls))
        params = ad.init(jax.random.key(1))
        batch = {k: v[:4] for k, v in clients[0].train.items()}
        x = ad.inputs(batch)
        for seg in ad.seg_names:
            x = ad.apply_seg(seg, params[seg], x, batch, False)
        full = ad.full_scores(params, batch)
        np.testing.assert_allclose(
            np.asarray(jax.nn.sigmoid(x.reshape(-1).astype(jnp.float32))),
            np.asarray(full), rtol=1e-6)


def test_lm_split_equals_full_forward():
    from repro.models.transformer import ModelConfig, TransformerLM
    cfg = ModelConfig(name="t", arch_type="dense", n_layers=4, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                      cut_layer=2, remat=False,
                      compute_dtype=jnp.float32)
    for nls in (False, True):
        model = TransformerLM.build(cfg, nls=nls)
        ad = lm_adapter(model)
        params = ad.init(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 12), 0, 97)
        batch = {"tokens": toks}
        x = ad.inputs(batch)
        for seg in ad.seg_names:
            x = ad.apply_seg(seg, params[seg], x, batch, False)
        direct, _, _ = model.apply(params, toks[:, :-1])
        np.testing.assert_allclose(np.asarray(x), np.asarray(direct),
                                   rtol=1e-5, atol=1e-5)
