"""Property-based tests (hypothesis) on system invariants."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.schedule import alternate_client, alternate_minibatch
from repro.core.strategies.base import tree_mean, tree_weighted_mean
from repro.train.metrics import auroc, auprc, f1_score, kappa
from repro.optim.schedules import wsd, cosine_warmup

_settings = settings(max_examples=40, deadline=None)


# ---------------------------------------------------------------------------
# schedules (the paper's AC/AM interleavings)
# ---------------------------------------------------------------------------

@_settings
@given(st.lists(st.integers(0, 12), min_size=1, max_size=6))
def test_schedules_are_permutations_of_same_batches(nb):
    ac = alternate_client(nb)
    am = alternate_minibatch(nb)
    assert sorted(ac) == sorted(am)
    assert len(ac) == sum(nb)
    # every (client, batch) appears exactly once
    assert len(set(ac)) == len(ac)


@_settings
@given(st.integers(1, 6), st.integers(1, 8))
def test_am_interleaves_clients(n_clients, nb):
    order = alternate_minibatch([nb] * n_clients)
    # with equal batch counts, the first n_clients entries hit each client
    first = [c for c, _ in order[:n_clients]]
    assert sorted(first) == list(range(n_clients))


@_settings
@given(st.lists(st.integers(1, 9), min_size=2, max_size=5))
def test_ac_is_client_contiguous(nb):
    order = alternate_client(nb)
    seen = [c for c, _ in order]
    # AC never returns to an earlier client
    assert seen == sorted(seen)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

@_settings
@given(st.lists(st.tuples(st.booleans(),
                          st.floats(-5, 5, allow_nan=False)),
                min_size=4, max_size=100))
def test_auroc_monotone_invariant(pairs):
    labels = np.array([p[0] for p in pairs])
    scores = np.array([p[1] for p in pairs])
    if labels.all() or (~labels).all():
        return
    a1 = auroc(labels, scores)
    # power-of-two scaling is exact in floating point: strictly monotonic
    # and introduces no ties (additive shifts would absorb subnormals)
    a2 = auroc(labels, scores * 4.0)
    assert abs(a1 - a2) < 1e-9
    assert 0.0 <= a1 <= 1.0


def test_auroc_separable_is_one():
    labels = np.array([0, 0, 0, 1, 1])
    scores = np.array([.1, .2, .3, .8, .9])
    assert auroc(labels, scores) == 1.0
    assert auprc(labels, scores) == 1.0
    assert f1_score(labels, scores) == 1.0
    assert kappa(labels, scores) == 1.0


@_settings
@given(st.lists(st.tuples(st.booleans(),
                          st.floats(0, 1, allow_nan=False)),
                min_size=4, max_size=60))
def test_metric_bounds(pairs):
    labels = np.array([p[0] for p in pairs])
    scores = np.array([p[1] for p in pairs])
    if labels.all() or (~labels).all():
        return
    assert 0.0 <= auprc(labels, scores) <= 1.0
    assert -1.0 <= kappa(labels, scores) <= 1.0


# ---------------------------------------------------------------------------
# aggregation (FedAvg invariants)
# ---------------------------------------------------------------------------

@_settings
@given(st.integers(1, 5), st.integers(1, 4))
def test_weighted_mean_equal_weights(n, leaves):
    trees = [{f"w{i}": jnp.full((3,), float(t + i))
              for i in range(leaves)} for t in range(n)]
    m1 = tree_mean(trees)
    m2 = tree_weighted_mean(trees, [7.0] * n)
    for a, b in zip(jax.tree.leaves(m1), jax.tree.leaves(m2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@_settings
@given(st.floats(0.1, 10, allow_nan=False))
def test_fedavg_of_identical_models_is_identity(v):
    trees = [{"w": jnp.full((4,), v)}] * 3
    m = tree_weighted_mean(trees, [1.0, 2.0, 3.0])
    np.testing.assert_allclose(np.asarray(m["w"]), v, rtol=1e-6)


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------

@_settings
@given(st.integers(1, 50), st.integers(1, 200), st.integers(1, 100))
def test_wsd_phases(warmup, stable, decay):
    peak = 1e-3
    fn = wsd(peak, warmup, stable, decay)
    assert float(fn(0)) <= peak * 1e-6 + 1e-12
    assert abs(float(fn(warmup)) - peak) < 1e-9
    assert abs(float(fn(warmup + stable)) - peak) < 1e-9
    end = float(fn(warmup + stable + decay))
    assert end <= peak * 0.1 + 1e-9
    # monotone non-increasing after stable phase
    xs = [float(fn(warmup + stable + i)) for i in range(0, decay, max(1, decay // 7))]
    assert all(a >= b - 1e-12 for a, b in zip(xs, xs[1:]))


@_settings
@given(st.integers(1, 20), st.integers(21, 100))
def test_cosine_bounds(warmup, total):
    fn = cosine_warmup(1.0, warmup, total)
    for s in range(0, total + 10, max(1, total // 9)):
        assert -1e-9 <= float(fn(s)) <= 1.0 + 1e-9


# ---------------------------------------------------------------------------
# wire simulator timelines (observability satellite)
# ---------------------------------------------------------------------------

def _sim_legs():
    """One cached leg-size dict — leg sizes depend only on the adapter and
    batch shape, so every hypothesis example reuses them."""
    global _SIM_CACHE
    try:
        return _SIM_CACHE
    except NameError:
        pass
    from repro.core.partition import cnn_adapter
    from repro.models.cnn import DenseNetConfig, build_densenet
    adapter = cnn_adapter(build_densenet(
        DenseNetConfig(growth=4, blocks=(1, 1), stem_ch=4, cut_layer=1)))
    batch = {"image": np.zeros((4, 8, 8, 1), np.float32),
             "label": np.zeros((4,), np.float32)}
    _SIM_CACHE = (adapter, batch)
    return _SIM_CACHE


@settings(max_examples=25, deadline=None)
@given(st.sampled_from(["sl_ac", "sl_am", "sflv2_ac", "sflv3_ac", "fl"]),
       st.lists(st.integers(0, 30), min_size=2, max_size=5),
       st.integers(1, 8))
def test_sim_timelines_ordered_and_byte_exact(method, n_train, batch_size):
    """``SimResult.timeline(client)`` invariants for any hospital mix:
    each client's transfers are time-ordered and non-overlapping (one
    serial link per client), and the event byte totals reproduce the
    analytic per-tag accounting (``core.comm.comm_per_epoch``) exactly —
    the same invariant ``Transport`` accounting sits on."""
    from repro.core.comm import comm_per_epoch
    from repro.wire.simulator import simulate
    adapter, batch = _sim_legs()
    n_val = [max(0, n // 2) for n in n_train]
    r = simulate(method, adapter, batch, n_train, n_val, batch_size,
                 network="hospital_wan")
    for c in range(len(n_train)):
        tl = r.timeline(c)
        assert all(e.client == c for e in tl)
        for a, b in zip(tl, tl[1:]):
            assert a.t_start <= a.t_end
            assert b.t_start >= a.t_end - 1e-9    # serialized link
    # every event appears in exactly one client's timeline
    assert sum(len(r.timeline(c)) for c in range(len(n_train))) == len(
        r.events)
    # per-tag byte totals == the analytic accounting, to the byte
    analytic = comm_per_epoch(method, adapter, batch, n_train, n_val,
                              batch_size)
    by_tag = {}
    for e in r.events:
        by_tag[e.tag] = by_tag.get(e.tag, 0.0) + e.nbytes
    assert set(by_tag) == {t for t, b in analytic.breakdown.items()
                           if b > 0}
    for tag, b in by_tag.items():
        assert b == pytest.approx(analytic.breakdown[tag]), tag
    assert r.bytes_on_wire == pytest.approx(
        sum(analytic.breakdown.values()))


# ---------------------------------------------------------------------------
# quantizer error bound (per-row int8)
# ---------------------------------------------------------------------------

@_settings
@given(st.integers(1, 6), st.integers(8, 64), st.floats(0.1, 8.0))
def test_quantize_roundtrip_bound(rows, cols, scale):
    from repro.kernels.act_compress.ref import roundtrip_ref
    x = (np.random.default_rng(0).normal(0, scale, (rows, cols))
         .astype(np.float32))
    rt = np.asarray(roundtrip_ref(jnp.asarray(x)))
    amax = np.abs(x).max(axis=1, keepdims=True)
    assert (np.abs(rt - x) <= amax / 127.0 + 1e-6).all()


# ---------------------------------------------------------------------------
# RDP accountant monotonicity (participation satellite: the amplified
# rate q_round * q_batch must never report MORE privacy spend than the
# unamplified run it bounds)
# ---------------------------------------------------------------------------

@_settings
@given(st.floats(0.7, 4.0), st.floats(0.01, 0.5), st.floats(0.5, 1.0),
       st.integers(1, 400))
def test_epsilon_monotone_in_sampling_rate(nm, q, bump, steps):
    """eps is non-decreasing in q — subsampling amplification: training a
    cohort at rate q_round * q never costs more than rate q."""
    from repro.privacy.accountant import epsilon
    lo = epsilon(nm, q * bump, steps)
    hi = epsilon(nm, q, steps)
    assert lo <= hi + 1e-12
    if bump < 0.999 and hi > 0:
        assert lo < hi                 # strictly amplified


@_settings
@given(st.floats(0.7, 4.0), st.floats(0.01, 1.0), st.integers(1, 300),
       st.integers(1, 300))
def test_epsilon_monotone_in_steps(nm, q, s1, extra):
    from repro.privacy.accountant import epsilon
    assert epsilon(nm, q, s1) <= epsilon(nm, q, s1 + extra) + 1e-12
    assert epsilon(nm, q, s1) < epsilon(nm, q, s1 + extra)


@_settings
@given(st.floats(0.7, 4.0), st.floats(0.01, 1.0), st.integers(1, 100),
       st.integers(1, 100))
def test_epsilon_round_composition_additive(nm, q, r1, r2):
    """Composing rounds incrementally == one shot at the total count (the
    RDP ledger is additive), so per-round accounting under participation
    matches whole-run accounting exactly."""
    from repro.privacy.accountant import RDPAccountant
    a = RDPAccountant(nm)
    a.step(q, r1)
    a.step(q, r2)
    b = RDPAccountant(nm)
    b.step(q, r1 + r2)
    assert a.steps == b.steps
    assert a.epsilon() == b.epsilon()


# ---------------------------------------------------------------------------
# secure aggregation == plain weighted mean (to fixed-point resolution)
# ---------------------------------------------------------------------------

@_settings
@given(st.integers(2, 6), st.integers(1, 3),
       st.lists(st.integers(1, 500), min_size=6, max_size=6),
       st.integers(0, 2 ** 16))
def test_secagg_matches_weighted_mean(n, leaves, weights, seed):
    """The pairwise masks must telescope to EXACTLY zero: the modular sum
    of masked fixed-point uploads equals the weighted mean to one
    quantization step per client, for any weights and group size."""
    from repro.privacy.secagg import SecAgg
    weights = weights[:n]
    rng = np.random.default_rng(7)
    trees = [{f"w{i}": jnp.asarray(rng.normal(0, 1, (5,)), jnp.float32)
              for i in range(leaves)} for _ in range(n)]
    out = SecAgg(n, seed=seed).aggregate_weighted(
        [jax.tree.map(np.asarray, t) for t in trees], list(weights))
    want = tree_weighted_mean(trees, weights)
    tol = n * 2.0 ** -15               # frac_bits=16 rounding per client
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64), atol=tol)
    # determinism: a fresh group with the same seed reproduces the bits
    out2 = SecAgg(n, seed=seed).aggregate_weighted(
        [jax.tree.map(np.asarray, t) for t in trees], list(weights))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(out2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# participation sampling invariants
# ---------------------------------------------------------------------------

@_settings
@given(st.integers(1, 40), st.integers(0, 200), st.integers(0, 2 ** 16))
def test_participation_fixed_draws(n, rnd, seed):
    from repro.core.participation import Participation
    k = max(1, n // 3)
    p = Participation(n_global=n, k=k, seed=seed)
    ids = p.round_ids(rnd)
    assert len(ids) == k == p.n_slots
    assert len(set(ids.tolist())) == k
    assert ((0 <= ids) & (ids < n)).all()
    assert np.array_equal(ids, np.sort(ids))
    assert np.array_equal(ids, p.round_ids(rnd))   # round-addressable


@_settings
@given(st.integers(2, 40), st.floats(0.05, 0.95), st.integers(0, 100),
       st.integers(0, 2 ** 16))
def test_participation_poisson_draws(n, q, rnd, seed):
    from repro.core.participation import Participation
    p = Participation(n_global=n, q=q, seed=seed)
    ids = p.round_ids(rnd)
    assert len(ids) <= p.n_slots == n
    assert len(set(ids.tolist())) == len(ids)
    assert all(0 <= i < n for i in ids.tolist())
    assert p.rate == q


# ---------------------------------------------------------------------------
# wire accounting: wire_bytes(spec) == the ACTUAL encoded payload bytes
# ---------------------------------------------------------------------------

@_settings
@given(st.integers(1, 7), st.integers(1, 65),
       st.sampled_from(["identity", "bf16", "int8", "topk:0.1", "topk:0.37",
                        "topk:1.0"]),
       st.sampled_from(["float32", "bfloat16"]),
       st.booleans())
def test_wire_bytes_matches_encoded_payload(rows, cols, name, dtype, three_d):
    """Table-4 byte accounting is analytic — gate it against what the
    codec's ``encode`` payload ACTUALLY occupies, leaf-exact (including
    the int8 per-row f32 scales and topk's int32 index bytes)."""
    from repro.wire.codec import make_codec
    codec = make_codec(name)
    shape = (rows, 2, cols) if three_d else (rows, cols)
    x = jnp.asarray(np.random.default_rng(1).normal(0, 2, shape),
                    jnp.dtype(dtype))
    payload = codec.encode(x)
    actual = sum(int(np.asarray(l).nbytes)
                 for l in jax.tree.leaves(payload))
    spec = jax.ShapeDtypeStruct(x.shape, x.dtype)
    assert codec.wire_bytes(spec) == actual
    assert codec.compression_ratio(spec) == pytest.approx(
        x.nbytes / actual)
