"""repro.core.participation: per-round client subsampling of N hospitals.

Acceptance gates (ISSUE 9):
  * ``Participation(n_global=N, k=N)`` is BIT-identical to
    ``participation=None`` for FL; the split family matches at the
    engine-parity tolerance 1e-5 (gather/scatter by slot id shifts XLA
    fusion boundaries by ~1 ulp — DESIGN.md §14);
  * a participating multi-epoch compiled run stays ONE dispatch;
  * a hospital's round is co-sample independent (keys and batches depend
    on (round, hospital), never on who else was drawn) and phantom slots
    never perturb the sampled rows;
  * an empty Poisson round keeps the previous globals (S1 guard);
  * the RDP accountant composes at the amplified rate: eps(K<N) is
    STRICTLY below eps(K=N);
  * wire accounting sees only sampled clients (EpochSchedule.client_set);
  * telemetry un-pads slot metrics to global columns with NaN rows.
"""

import jax
import numpy as np
import pytest

from repro import optim as O
from repro.core.partition import cnn_adapter
from repro.core.participation import Participation, as_participation
from repro.core.strategies import make_strategy
from repro.data.synthetic import make_cxr_clients
from repro.models.cnn import DenseNetConfig, build_densenet
from repro.privacy import PrivacyConfig

DP = PrivacyConfig(noise_multiplier=1.1, clip_norm=1.0)
SPLIT_METHODS = ["sl_ac", "sl_am", "sflv2_ac", "sflv3_ac", "sflv1_ac"]


@pytest.fixture(scope="module")
def tiny_setup():
    clients = make_cxr_clients(seed=0, train_per_client=[17, 12, 9],
                               val_per_client=6, test_per_client=7,
                               image_size=16, n_clients=3)
    cfg = DenseNetConfig(growth=4, blocks=(1, 1), stem_ch=8, cut_layer=1)
    return clients, cnn_adapter(build_densenet(cfg))


def _train(method, clients, adapter, participation, privacy=None,
           epochs=2, batch=4, transport=None, observe=None,
           engine="compiled"):
    st = make_strategy(method, adapter, lambda: O.adam(1e-3), len(clients),
                       privacy=privacy, engine=engine, transport=transport,
                       observe=observe, participation=participation)
    state = st.setup(jax.random.key(0))
    state, logs = st.run(state, [c.train for c in clients],
                         np.random.default_rng(0), batch, epochs)
    return st, state, logs


def _leaves(st, state, client=0):
    return [np.asarray(x) for x in
            jax.tree.leaves(st.params_for_eval(state, client))]


# ---------------------------------------------------------------------------
# the frozen spec
# ---------------------------------------------------------------------------

def test_spec_validation():
    with pytest.raises(ValueError, match="exactly one"):
        Participation(n_global=5)
    with pytest.raises(ValueError, match="exactly one"):
        Participation(n_global=5, k=2, q=0.5)
    with pytest.raises(ValueError):
        Participation(n_global=5, k=0)
    with pytest.raises(ValueError):
        Participation(n_global=5, k=6)
    with pytest.raises(ValueError):
        Participation(n_global=5, q=0.0)
    with pytest.raises(ValueError):
        Participation(n_global=5, q=1.5)
    with pytest.raises(ValueError, match="ids must be in"):
        Participation(n_global=3, schedule=((0, 5),))
    with pytest.raises(ValueError, match="repeat"):
        Participation(n_global=3, schedule=((1, 1),))
    with pytest.raises(ValueError, match="slots"):
        Participation(n_global=5, k=2, slots=0)
    with pytest.raises(TypeError):
        as_participation("k=2")
    assert as_participation(None) is None


def test_spec_kinds_slots_rate():
    p = Participation(n_global=10, k=3)
    assert (p.kind, p.n_slots, p.rate) == ("fixed", 3, 0.3)
    p = Participation(n_global=10, q=0.25)
    assert (p.kind, p.n_slots, p.rate) == ("poisson", 10, 0.25)
    p = Participation(n_global=10, q=0.25, slots=4)
    assert p.n_slots == 4
    p = Participation(n_global=10, schedule=((1, 0), (2,)))
    assert (p.kind, p.n_slots, p.rate) == ("schedule", 2, 1.0)
    assert p.schedule[0] == (0, 1)            # normalized sorted


def test_round_ids_deterministic_and_bounded():
    p = Participation(n_global=50, k=7, seed=3)
    a, b = p.round_ids(5), p.round_ids(5)
    assert np.array_equal(a, b)
    assert len(a) == 7 and len(set(a.tolist())) == 7
    assert not np.array_equal(p.round_ids(5), p.round_ids(6))
    # poisson truncation: never more than slots
    p = Participation(n_global=50, q=0.9, slots=5, seed=1)
    for r in range(6):
        assert len(p.round_ids(r)) <= 5
    # schedule replay + exhaustion
    p = Participation(n_global=4, schedule=((0, 2), (1,)))
    assert p.round_ids(1).tolist() == [1]
    with pytest.raises(ValueError, match="rounds"):
        p.round_ids(2)


# ---------------------------------------------------------------------------
# K = N parity (acceptance: FL bit-identical; split family at 1e-5)
# ---------------------------------------------------------------------------

def test_fl_k_equals_n_bit_identical(tiny_setup):
    clients, adapter = tiny_setup
    st0, s0, l0 = _train("fl", clients, adapter, None, privacy=DP)
    st1, s1, l1 = _train("fl", clients, adapter,
                         Participation(n_global=3, k=3), privacy=DP)
    for a, b in zip(_leaves(st0, s0), _leaves(st1, s1)):
        assert np.array_equal(a, b)           # BIT-identical
    la = np.sort(np.concatenate([np.asarray(l.losses) for l in l0]))
    lb = np.sort(np.concatenate([np.asarray(l.losses) for l in l1]))
    np.testing.assert_array_equal(la, lb)
    # accountant: same steps, same epsilon (rate is K/N = 1)
    ra, rb = st0.privacy_report(), st1.privacy_report()
    for x, y in zip(ra, rb):
        assert x["steps"] == y["steps"]
        assert abs(x["epsilon"] - y["epsilon"]) < 1e-9


@pytest.mark.parametrize("method", SPLIT_METHODS)
def test_split_k_equals_n_parity(method, tiny_setup):
    clients, adapter = tiny_setup
    st0, s0, _ = _train(method, clients, adapter, None)
    st1, s1, _ = _train(method, clients, adapter,
                        Participation(n_global=3, k=3))
    for c in range(3):
        for a, b in zip(_leaves(st0, s0, c), _leaves(st1, s1, c)):
            np.testing.assert_allclose(a, b, atol=1e-5)


# ---------------------------------------------------------------------------
# one dispatch + privacy amplification
# ---------------------------------------------------------------------------

def test_fl_participation_one_dispatch_and_amplification(tiny_setup):
    clients, adapter = tiny_setup
    st2, _, _ = _train("fl", clients, adapter,
                       Participation(n_global=3, k=2), privacy=DP,
                       epochs=3)
    assert st2._dispatches == 1
    st3, _, _ = _train("fl", clients, adapter,
                       Participation(n_global=3, k=3), privacy=DP,
                       epochs=3)
    eps2 = max(r["epsilon"] for r in st2.privacy_report())
    eps3 = max(r["epsilon"] for r in st3.privacy_report())
    assert eps2 < eps3                        # STRICT amplification


@pytest.mark.parametrize("method", ["sl_ac", "sflv3_ac"])
def test_split_participation_one_dispatch(method, tiny_setup):
    clients, adapter = tiny_setup
    st, state, _ = _train(method, clients, adapter,
                          Participation(n_global=3, k=2), privacy=DP,
                          epochs=2)
    assert st._dispatches == 1
    for a in _leaves(st, state, 0):
        assert np.all(np.isfinite(a))


# ---------------------------------------------------------------------------
# co-sample independence + phantom invariance
# ---------------------------------------------------------------------------

def test_cosample_independence(tiny_setup):
    """Hospital 0's round-0 training is identical whether its cohort
    partner is hospital 1 or hospital 2 (batches and noise keys are
    keyed by GLOBAL row, not slot context)."""
    clients, adapter = tiny_setup
    _, _, la = _train("fl", clients, adapter,
                      Participation(n_global=3, schedule=((0, 1),)),
                      privacy=DP, epochs=1)
    _, _, lb = _train("fl", clients, adapter,
                      Participation(n_global=3, schedule=((0, 2),)),
                      privacy=DP, epochs=1)
    nb0 = 17 // 4                              # hospital 0's batches
    a = np.asarray(la[0].losses if isinstance(la, list) else la.losses)
    b = np.asarray(lb[0].losses if isinstance(lb, list) else lb.losses)
    np.testing.assert_array_equal(a[:nb0], b[:nb0])


def test_phantom_invariance(tiny_setup):
    """Widening the slot axis beyond the cohort (phantom rows) must not
    perturb the sampled hospitals' results."""
    clients, adapter = tiny_setup
    sched = ((0, 1), (1, 2))
    st2, s2, _ = _train("fl", clients, adapter,
                        Participation(n_global=3, schedule=sched, slots=2))
    st3, s3, _ = _train("fl", clients, adapter,
                        Participation(n_global=3, schedule=sched, slots=3))
    for a, b in zip(_leaves(st2, s2), _leaves(st3, s3)):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_empty_poisson_round_keeps_params(tiny_setup):
    """S1 end-to-end: a Poisson round that samples nobody is a no-op on
    the globals — never a divide-by-zero NaN."""
    clients, adapter = tiny_setup
    seed = next(s for s in range(1000)
                if len(Participation(n_global=3, q=0.05,
                                     seed=s).round_ids(0)) == 0)
    st = make_strategy("fl", adapter, lambda: O.adam(1e-3), 3,
                       participation=Participation(n_global=3, q=0.05,
                                                   seed=seed))
    state = st.setup(jax.random.key(0))
    before = _leaves(st, state)
    state, _ = st.run(state, [c.train for c in clients],
                      np.random.default_rng(0), 4, 1)
    after = _leaves(st, state)
    for a, b in zip(before, after):
        assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# scope validation
# ---------------------------------------------------------------------------

def test_unsupported_combinations_raise(tiny_setup):
    clients, adapter = tiny_setup
    part = Participation(n_global=3, k=2)
    mk = lambda method, **kw: make_strategy(  # noqa: E731
        method, adapter, lambda: O.adam(1e-3), 3, **kw)
    with pytest.raises(ValueError, match="centralized"):
        mk("centralized", participation=part)
    with pytest.raises(ValueError, match="compiled"):
        mk("fl", participation=part, engine="stepwise")
    with pytest.raises(ValueError, match="shard"):
        mk("fl", participation=part, shard=True)
    with pytest.raises(ValueError, match="secagg"):
        mk("fl", participation=part,
           privacy=PrivacyConfig(secagg=True))
    with pytest.raises(ValueError, match="n_global"):
        mk("fl", participation=Participation(n_global=5, k=2))
    # split family: fixed-size cohorts only, no observe
    with pytest.raises(ValueError, match="fixed"):
        mk("sl_ac", participation=Participation(n_global=3, q=0.5))
    from repro.obs import Telemetry
    with pytest.raises(ValueError, match="observe"):
        mk("sl_ac", participation=part, observe=Telemetry())


# ---------------------------------------------------------------------------
# wire accounting: only sampled clients ship bytes
# ---------------------------------------------------------------------------

def test_wire_client_set_and_sampled_bytes(tiny_setup):
    from repro.wire import Transport
    clients, adapter = tiny_setup
    tp_full = Transport("identity")
    _train("sl_ac", clients, adapter, None, transport=tp_full, epochs=2)
    tp = Transport("identity")
    _train("sl_ac", clients, adapter, Participation(n_global=3, k=2),
           transport=tp, epochs=2)
    assert 0 < tp.bytes_on_wire < tp_full.bytes_on_wire
    assert len(tp.epoch_log) == 2
    for ep in tp.epoch_log:
        assert ep.client_set is not None and len(ep.client_set) == 2
        for c in range(3):
            if c not in ep.client_set:
                assert ep.tr_counts[c] == 0
            else:
                assert ep.tr_counts[c] > 0


# ---------------------------------------------------------------------------
# telemetry: slot metrics un-pad to global columns, NaN where unsampled
# ---------------------------------------------------------------------------

def test_telemetry_participation_columns(tiny_setup):
    from repro.obs import Telemetry
    clients, adapter = tiny_setup
    sched = ((0, 1), (1, 2))
    st, _, _ = _train("fl", clients, adapter,
                      Participation(n_global=3, schedule=sched),
                      observe=Telemetry(), epochs=2)
    rt = st.last_run_telemetry
    assert rt is not None and len(rt.rounds) == 2
    for e, r in enumerate(rt.rounds):
        assert r.participation.tolist() == list(sched[e])
        out = [v for v in r.metrics.values()
               if np.asarray(v).shape == (3,)]
        assert out, "expected per-hospital metric columns"
        unsampled = [c for c in range(3) if c not in sched[e]][0]
        for v in out:
            v = np.asarray(v)
            assert np.isnan(v[unsampled])
            assert np.all(np.isfinite(v[list(sched[e])]))
        assert "participation" in r.to_json()
