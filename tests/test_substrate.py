"""Substrate tests: data generator, optimizers, schedules, checkpointing."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim as O
from repro.data.synthetic import (batches, make_cxr_clients,
                                  pooled, token_stream)
from repro.train import checkpoint


def test_cxr_clients_structure():
    cl = make_cxr_clients(seed=0, train_per_client=[24, 16, 24, 16, 24],
                          val_per_client=10, test_per_client=10,
                          image_size=32)
    assert len(cl) == 5
    assert cl[0].train["image"].shape == (24, 32, 32, 1)
    assert cl[1].train["image"].shape == (16, 32, 32, 1)
    # prevalence: 50% train, 10% eval (approximately, small-n)
    labs = np.concatenate([c.train["label"] for c in cl])
    assert 0.3 < labs.mean() < 0.7
    # masks only on positives
    for c in cl:
        pos = c.train["label"] > 0.5
        assert c.train["mask"][pos].sum() > 0
        assert c.train["mask"][~pos].sum() == 0


def test_clients_are_non_iid():
    cl = make_cxr_clients(seed=0, train_per_client=64, val_per_client=8,
                          test_per_client=8, image_size=32)
    means = [c.train["image"].mean() for c in cl]
    assert np.std(means) > 0.01      # scanner shifts move the statistics


def test_pooled_and_batches():
    cl = make_cxr_clients(seed=0, train_per_client=16, val_per_client=8,
                          test_per_client=8, image_size=16)
    pool = pooled(cl, "train")
    assert len(pool["label"]) == 5 * 16
    bs = list(batches(pool, 32, np.random.default_rng(0)))
    assert len(bs) == 2 and bs[0]["image"].shape[0] == 32


def test_token_stream_learnable_structure():
    toks = token_stream(0, vocab=64, n_seqs=8, seq_len=128)
    assert toks.shape == (8, 128) and toks.max() < 64
    # Markov source: conditional entropy < unconditional entropy
    flat, nxt = toks[:, :-1].ravel(), toks[:, 1:].ravel()
    joint = {}
    for a, b in zip(flat, nxt):
        joint.setdefault(a, []).append(b)
    cond_modes = np.mean([np.bincount(v).max() / len(v)
                          for v in joint.values() if len(v) > 4])
    uncond_mode = np.bincount(nxt).max() / len(nxt)
    assert cond_modes > uncond_mode + 0.05


def test_adam_converges_on_quadratic():
    opt = O.adam(0.1)
    p = {"w": jnp.array([5.0, -3.0])}
    s = opt.init(p)
    for _ in range(200):
        g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
        u, s = opt.update(g, s, p)
        p = O.apply_updates(p, u)
    assert float(jnp.abs(p["w"]).max()) < 1e-2


def test_sgd_momentum_and_clip():
    opt = O.chain(O.clip_by_global_norm(1.0), O.sgd(0.5, momentum=0.9))
    p = {"w": jnp.array([10.0])}
    s = opt.init(p)
    g = {"w": jnp.array([100.0])}     # must be clipped to norm 1
    u, s = opt.update(g, s, p)
    assert abs(float(u["w"][0])) <= 0.5 + 1e-6


def test_adam_bf16_states():
    opt = O.adam(1e-3, state_dtype=jnp.bfloat16)
    p = {"w": jnp.ones((4,), jnp.float32)}
    s = opt.init(p)
    assert s["mu"]["w"].dtype == jnp.bfloat16
    u, s = opt.update({"w": jnp.ones((4,))}, s, p)
    assert jnp.isfinite(u["w"]).all()


def test_checkpoint_roundtrip():
    tree = {"a": {"b": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "c": [jnp.ones((4,), jnp.int32), jnp.zeros((2,), jnp.bfloat16)]}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.msgpack")
        checkpoint.save(path, tree)
        back = checkpoint.load(path, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
