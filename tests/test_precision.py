"""Mixed-precision gates (DESIGN.md §13): bf16 compute, fp32 masters.

``make_strategy(..., precision="bf16")`` casts segment params and boundary
activations to bfloat16 for TRAINING forward/backward only; master params,
optimizer state, FedAvg / server-Adam accumulation and all EVAL passes stay
fp32.  Gates here: masters never leave fp32, eval is precision-independent,
and the smoke-config AUROC lands within the stated |Δ| <= 0.05 of fp32.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim as O
from repro.core.partition import PRECISIONS, cast_adapter, cnn_adapter
from repro.core.strategies import make_strategy
from repro.data.synthetic import make_cxr_clients
from repro.models.cnn import DenseNetConfig, build_densenet


@pytest.fixture(scope="module")
def setup():
    clients = make_cxr_clients(seed=0, n_clients=3, train_per_client=24,
                               val_per_client=8, test_per_client=16,
                               image_size=16)
    cfg = DenseNetConfig(growth=4, blocks=(1, 1), stem_ch=8, cut_layer=1)
    return clients, cnn_adapter(build_densenet(cfg))


def _train_eval(method, precision, clients, adapter, epochs=2):
    st = make_strategy(method, adapter, lambda: O.adam(1e-3), len(clients),
                       precision=precision)
    state = st.setup(jax.random.key(0))
    state, logs = st.run(state, [c.train for c in clients],
                         np.random.default_rng(0), 4, epochs)
    m = st.evaluate(state, clients, "test", batch_size=8)
    return st, state, logs, m


# ---------------------------------------------------------------------------
# cast_adapter unit behavior
# ---------------------------------------------------------------------------

def test_cast_adapter_fp32_is_identity(setup):
    _, adapter = setup
    assert cast_adapter(adapter, "fp32") is adapter


def test_cast_adapter_rejects_unknown_precision(setup):
    clients, adapter = setup
    with pytest.raises(ValueError):
        cast_adapter(adapter, "fp16")
    with pytest.raises(ValueError):
        make_strategy("sl_am", adapter, lambda: O.adam(1e-3), len(clients),
                      precision="tf32")
    assert "bf16" in PRECISIONS


def test_cast_adapter_train_only(setup):
    """train=True computes in bf16; train=False (eval) stays full precision."""
    clients, adapter = setup
    bf = cast_adapter(adapter, "bf16")
    params = adapter.init(jax.random.key(0))
    batch = {k: v[:4] for k, v in clients[0].train.items()}
    seg = adapter.seg_names[0]
    x = adapter.inputs(batch)
    train_out = bf.apply_seg(seg, params[seg], x, batch, True)
    eval_out = bf.apply_seg(seg, params[seg], x, batch, False)
    assert all(l.dtype == jnp.bfloat16
               for l in jax.tree.leaves(train_out)
               if jnp.issubdtype(l.dtype, jnp.floating))
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(eval_out)[0], np.float32),
        np.asarray(jax.tree.leaves(
            adapter.apply_seg(seg, params[seg], x, batch, False))[0],
            np.float32))


# ---------------------------------------------------------------------------
# strategy-level gates
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["fl", "sl_am"])
def test_bf16_masters_stay_fp32(method, setup):
    clients, adapter = setup
    st, state, logs, m = _train_eval(method, "bf16", clients, adapter,
                                     epochs=1)
    for i in range(len(clients)):
        for l in jax.tree.leaves(st.params_for_eval(state, i)):
            assert l.dtype in (jnp.float32, jnp.int32), l.dtype
    assert all(np.isfinite(l.losses).all() for l in logs)
    assert 0.0 <= m["auroc"] <= 1.0


@pytest.mark.slow
@pytest.mark.parametrize("method", ["fl", "sl_am", "sflv2_ac"])
def test_bf16_auroc_within_tolerance(method, setup):
    """The §13 acceptance gate: |AUROC(bf16) - AUROC(fp32)| <= 0.05."""
    clients, adapter = setup
    _, _, _, m32 = _train_eval(method, "fp32", clients, adapter)
    _, _, _, m16 = _train_eval(method, "bf16", clients, adapter)
    assert abs(m16["auroc"] - m32["auroc"]) <= 0.05, (m16, m32)
