"""Per-epoch communication accounting (paper Table 4).

All quantities are derived analytically from activation/parameter pytree
byte sizes via ``jax.eval_shape`` — the same numbers the paper measured over
PySyft sockets.  The dry-run cross-checks them against HLO collective bytes,
and ``repro.wire.simulator`` replays the same legs through a network model
(the analytic total is the simulator's conservation cross-check).

One epoch = training over all train batches + validation over all val
batches (paper §4.3).  Per train batch the cut-layer traffic is:
  LS : activations up + activation-gradients down           (front<->middle)
  NLS: + hidden down + hidden-gradients up                  (middle<->tail)
Validation moves activations only (no gradients).  Breakdown keys name
the transfer's physical direction (client->server = up, server->client =
down): in the U-shaped NLS split the server's hidden output travels DOWN
to the client-held tail and its gradient back UP — ``repro.wire`` tags
its simulated transfers with the same keys, so per-tag breakdowns are
comparable across the analytic and simulated accounting.

FL moves 2 x model bytes per client per round; SFLv2 additionally moves the
client segment back and forth for fed-averaging; SFLv3's averaged segment
lives on the server so no extra transfer occurs.

The shared primitives (``client_batch_counts``, ``leg_sizes``) are consumed
by both ``comm_per_epoch`` and the wire simulator so the two can never
drift apart.  A ``codec`` (repro.wire.codec) shrinks the activation legs
only — model/segment syncs ship raw parameters.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.core.partition import SplitAdapter, leaf_bytes


@dataclasses.dataclass(frozen=True)
class CommProfile:
    method: str
    bytes_per_epoch: float
    breakdown: dict

    @property
    def gb(self):
        return self.bytes_per_epoch / 1e9


def _batch_count(n_samples: int, batch_size: int) -> int:
    return n_samples // batch_size


def client_batch_counts(n_train: list[int], n_val: list[int],
                        batch_size: int) -> tuple[list[int], list[int]]:
    """Per-client (train, val) batch counts — one epoch's step grid.

    Validation always runs at least one (possibly short) batch per client.
    """
    tr = [_batch_count(n, batch_size) for n in n_train]
    va = [_batch_count(max(n, batch_size), batch_size) if n >= batch_size
          else 1 for n in n_val]
    return tr, va


def leg_sizes(adapter: SplitAdapter, example_batch: dict, params=None,
              codec=None) -> dict:
    """Per-occurrence byte size of every transfer type ("leg").

    ``act_fm``/``act_mt`` are the on-wire sizes of one batch's cut-layer
    activations (front->middle / middle->tail), shrunk by ``codec`` when
    given; ``*_raw`` are the uncompressed sizes.  ``model`` and
    ``client_seg`` are parameter syncs and never pass through a codec.
    """
    if params is None:
        params = jax.eval_shape(adapter.init, jax.random.key(0))
    specs = adapter.boundary_specs(example_batch, params)

    def wire(tree):
        if codec is None:
            return leaf_bytes(tree)
        return int(sum(codec.wire_bytes(l) for l in jax.tree.leaves(tree)))

    fm = specs["front->middle"]
    mt = specs.get("middle->tail", ()) if adapter.nls else ()
    return {
        "model": leaf_bytes(params),
        "client_seg": leaf_bytes(params["front"]) + (
            leaf_bytes(params["tail"]) if adapter.nls else 0),
        "act_fm": wire(fm),
        "act_fm_raw": leaf_bytes(fm),
        "act_mt": wire(mt) if adapter.nls else 0,
        "act_mt_raw": leaf_bytes(mt) if adapter.nls else 0,
    }


def comm_per_epoch(method: str, adapter: SplitAdapter, example_batch: dict,
                   n_train: list[int], n_val: list[int],
                   batch_size: int, codec=None) -> CommProfile:
    """``n_train``/``n_val``: per-client sample counts."""
    legs = leg_sizes(adapter, example_batch, codec=codec)
    tr_counts, va_counts = client_batch_counts(n_train, n_val, batch_size)
    train_batches, val_batches = sum(tr_counts), sum(va_counts)
    act_fm, act_mt = legs["act_fm"], legs["act_mt"]

    bd = {}
    if method == "centralized":
        total = 0.0
    elif method == "fl":
        n_clients = len(n_train)
        bd["model_down"] = legs["model"] * n_clients
        bd["model_up"] = legs["model"] * n_clients
        total = sum(bd.values())
    else:
        # SL / SFLv2 / SFLv3 share the cut-layer activation traffic
        bd["train_act_up"] = act_fm * train_batches
        bd["train_grad_down"] = act_fm * train_batches
        bd["val_act_up"] = act_fm * val_batches
        if adapter.nls:
            bd["train_hidden_down"] = act_mt * train_batches
            bd["train_hidden_grad_up"] = act_mt * train_batches
            bd["val_hidden_down"] = act_mt * val_batches
        if method.startswith("sflv2") or method.startswith("sflv1"):
            # client segments shipped to fed server and back for averaging
            bd["client_seg_avg"] = 2 * legs["client_seg"] * len(n_train)
        total = sum(bd.values())
    return CommProfile(method, float(total), bd)
