"""Per-epoch communication accounting (paper Table 4).

All quantities are derived analytically from activation/parameter pytree
byte sizes via ``jax.eval_shape`` — the same numbers the paper measured over
PySyft sockets.  The dry-run cross-checks them against HLO collective bytes.

One epoch = training over all train batches + validation over all val
batches (paper §4.3).  Per train batch the cut-layer traffic is:
  LS : activations up + activation-gradients down           (front<->middle)
  NLS: + hidden up + hidden-gradients down                  (middle<->tail)
Validation moves activations only (no gradients).

FL moves 2 x model bytes per client per round; SFLv2 additionally moves the
client segment back and forth for fed-averaging; SFLv3's averaged segment
lives on the server so no extra transfer occurs.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core.partition import SplitAdapter, leaf_bytes


@dataclasses.dataclass(frozen=True)
class CommProfile:
    method: str
    bytes_per_epoch: float
    breakdown: dict

    @property
    def gb(self):
        return self.bytes_per_epoch / 1e9


def _batch_count(n_samples: int, batch_size: int) -> int:
    return n_samples // batch_size


def comm_per_epoch(method: str, adapter: SplitAdapter, example_batch: dict,
                   n_train: list[int], n_val: list[int],
                   batch_size: int) -> CommProfile:
    """``n_train``/``n_val``: per-client sample counts."""
    params = jax.eval_shape(adapter.init, jax.random.key(0))
    model_bytes = leaf_bytes(params)
    client_bytes = leaf_bytes(params["front"]) + (
        leaf_bytes(params["tail"]) if adapter.nls else 0)

    specs = adapter.boundary_specs(example_batch, params)
    act_fm = leaf_bytes(specs["front->middle"])         # per batch
    act_mt = leaf_bytes(specs.get("middle->tail", ())) if adapter.nls else 0

    train_batches = sum(_batch_count(n, batch_size) for n in n_train)
    val_batches = sum(_batch_count(max(n, batch_size), batch_size)
                      if n >= batch_size else 1 for n in n_val)

    bd = {}
    if method == "centralized":
        total = 0.0
    elif method == "fl":
        n_clients = len(n_train)
        bd["model_down"] = model_bytes * n_clients
        bd["model_up"] = model_bytes * n_clients
        total = sum(bd.values())
    else:
        # SL / SFLv2 / SFLv3 share the cut-layer activation traffic
        bd["train_act_up"] = act_fm * train_batches
        bd["train_grad_down"] = act_fm * train_batches
        bd["val_act_up"] = act_fm * val_batches
        if adapter.nls:
            bd["train_hidden_up"] = act_mt * train_batches
            bd["train_hidden_grad_down"] = act_mt * train_batches
            bd["val_hidden_up"] = act_mt * val_batches
        if method.startswith("sflv2") or method.startswith("sflv1"):
            # client segments shipped to fed server and back for averaging
            bd["client_seg_avg"] = 2 * client_bytes * len(n_train)
        total = sum(bd.values())
    return CommProfile(method, float(total), bd)
