"""Per-round client participation: sampling K of N global hospitals.

Production federations do not train every enrolled hospital every round —
each round samples K participants out of N >> K (fixed-size uniform
sampling) or includes each hospital independently with probability q
(Poisson sampling, the variant the subsampled-Gaussian RDP bound is
stated for).  A frozen ``Participation`` spec makes that a first-class
layer:

  * the compiled engine packs each round's SAMPLED hospitals into a
    FIXED ``slots``-wide hospital axis (``engine.pack_participation_run``)
    — who participates is a per-round weights/ids change riding the scan
    as inputs, never a shape change, so a whole multi-epoch run stays
    ONE XLA dispatch and compute scales with K, not N;
  * sampling draws come from the spec's OWN ``(seed, round)`` streams —
    they never perturb the data-shuffle rng, and a hospital's batch
    composition and DP/cut-noise keys depend only on (round, hospital),
    never on who else was sampled (co-sample independence);
  * the RDP accountant composes each round at the amplified rate
    ``q_round * q_batch`` (``Strategy._dp_account(q_scale=...)``);
  * ``wire`` accounting and the simulator only see sampled clients'
    transfers (``Transport.record_epoch(client_set=...)``).

``Participation(n_global=N, k=N)`` is bit-identical to no participation.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Participation:
    """Frozen per-round sampling spec.

    Exactly one of:
      * ``k``        — fixed-size: K hospitals uniformly without
                       replacement each round;
      * ``q``        — Poisson: each hospital independently with
                       probability q each round;
      * ``schedule`` — an explicit tuple of per-round hospital-id tuples
                       (replay / tests / external samplers).  Determinism
                       means NO sampling randomness, so schedules get no
                       privacy amplification (``rate`` is 1).

    ``slots`` is the packed hospital-axis width: K for fixed-size; for
    Poisson it defaults to ``n_global`` and, when set smaller, rounds
    that draw more than ``slots`` hospitals keep a uniform ``slots``-
    subset of the draw (documented truncation — the amplified accountant
    rate stays q, an upper bound on the truncated inclusion rate).
    ``seed`` feeds ``(seed, round)`` counter streams, so draws are
    round-addressable and independent of everything else in the run.
    """
    n_global: int
    k: int | None = None
    q: float | None = None
    schedule: tuple = None
    seed: int = 0
    slots: int | None = None

    def __post_init__(self):
        if self.n_global < 1:
            raise ValueError("n_global must be >= 1")
        given = [self.k is not None, self.q is not None,
                 self.schedule is not None]
        if sum(given) != 1:
            raise ValueError("give exactly one of k=, q=, schedule=")
        if self.k is not None and not 1 <= self.k <= self.n_global:
            raise ValueError(f"k must be in [1, {self.n_global}]")
        if self.q is not None and not 0.0 < self.q <= 1.0:
            raise ValueError("q must be in (0, 1]")
        if self.schedule is not None:
            sched = tuple(tuple(sorted(int(i) for i in r))
                          for r in self.schedule)
            for r in sched:
                if any(not 0 <= i < self.n_global for i in r):
                    raise ValueError("schedule ids must be in "
                                     f"[0, {self.n_global})")
                if len(set(r)) != len(r):
                    raise ValueError("schedule rounds must not repeat ids")
            object.__setattr__(self, "schedule", sched)
        if self.slots is not None and self.slots < 1:
            raise ValueError("slots must be >= 1")

    @property
    def kind(self) -> str:
        if self.k is not None:
            return "fixed"
        if self.q is not None:
            return "poisson"
        return "schedule"

    @property
    def n_slots(self) -> int:
        if self.slots is not None:
            return self.slots
        if self.k is not None:
            return self.k
        if self.q is not None:
            return self.n_global
        return max((len(r) for r in self.schedule), default=1) or 1

    @property
    def rate(self) -> float:
        """Per-round inclusion probability for the amplified accountant.
        Deterministic schedules have no sampling randomness: rate 1."""
        if self.k is not None:
            return self.k / self.n_global
        if self.q is not None:
            return self.q
        return 1.0

    def round_ids(self, round_index: int) -> np.ndarray:
        """Sorted global hospital ids sampled for one round."""
        if self.schedule is not None:
            if round_index >= len(self.schedule):
                raise ValueError(
                    f"schedule has {len(self.schedule)} rounds; "
                    f"round {round_index} requested")
            return np.asarray(self.schedule[round_index], np.int64)
        rng = np.random.default_rng([self.seed, round_index])
        if self.k is not None:
            ids = rng.choice(self.n_global, size=self.k, replace=False)
        else:
            ids = np.flatnonzero(rng.random(self.n_global) < self.q)
            if len(ids) > self.n_slots:
                ids = rng.choice(ids, size=self.n_slots, replace=False)
        return np.sort(ids.astype(np.int64))


def as_participation(spec) -> Participation | None:
    """``None`` passes through; a ``Participation`` validates its width."""
    if spec is None or isinstance(spec, Participation):
        return spec
    raise TypeError("participation= must be a Participation or None, "
                    f"got {type(spec).__name__}")


__all__ = ["Participation", "as_participation"]
