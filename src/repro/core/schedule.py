"""Client interleaving schedules for split learning (paper §3.4).

* ``alternate_client`` (AC): each client trains on its ENTIRE local data,
  clients taken in order — long single-client runs on the shared server
  segment (the cause of catastrophic forgetting the paper discusses).
* ``alternate_minibatch`` (AM, the paper's proposal): clients take turns at
  MINI-BATCH granularity; a client that runs out of batches drops out while
  the rest continue — the server segment sees an interleaved stream.
"""

from __future__ import annotations


def alternate_client(n_batches: list[int]) -> list[tuple[int, int]]:
    order = []
    for c, nb in enumerate(n_batches):
        order.extend((c, b) for b in range(nb))
    return order


def alternate_minibatch(n_batches: list[int]) -> list[tuple[int, int]]:
    order = []
    for b in range(max(n_batches, default=0)):
        for c, nb in enumerate(n_batches):
            if b < nb:
                order.append((c, b))
    return order


SCHEDULES = {"ac": alternate_client, "am": alternate_minibatch}


def schedule_array(name: str, n_batches: list[int]):
    """Schedule as a dense ``[steps, 2]`` int32 array of (client, batch).

    This is the form the compiled engine consumes: a ``lax.scan`` over the
    leading axis replays the exact interleaving of the Python schedule —
    AM drop-out of exhausted clients is already folded into the rows, so
    the scan needs no masking or branching.
    """
    import numpy as np
    rows = SCHEDULES[name](list(n_batches))
    return np.asarray(rows, dtype=np.int32).reshape(-1, 2)
