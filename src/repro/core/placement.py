"""Hospital-axis device placement — pad-to-mesh sharding.

The paper's central comparison (FL vs SL vs SplitFed on per-hospital
cohorts) is embarrassingly parallel over hospitals, and every compiled
program in ``core/strategies/engine.py`` carries a ``[n_clients, ...]``
(hospital-leading) axis: epoch batch stacks ``[C, NB, B, ...]``, whole-run
stacks ``[E, C, NB, B, ...]``, stacked client params/opt state, per-step
key-index grids, and the batched-eval data stacks.  ``Placement`` makes
that axis a first-class sharded dimension:

  * **mesh** — a 1-D ``("hosp",)`` mesh over the local devices (real
    accelerators, or ``XLA_FLAGS=--xla_force_host_platform_device_count``
    virtual host devices).  On a single device the mesh is ``None`` and
    every placement op is the identity — the engine runs unchanged.
  * **pad-to-mesh** — ``n_clients`` is padded UP to the next device
    multiple with *phantom hospitals*: zero-sample clients whose batch
    rows are zeros, whose pad-and-mask rows are all invalid, and whose
    FedAvg / server-gradient / client-sync weights are exactly zero.  The
    engine's existing pad-and-mask machinery then guarantees losses,
    aggregated params, metrics, DP accounting, and wire byte meters are
    unaffected (asserted in tests/test_placement.py) — so ANY hospital
    count runs on ANY device count.
  * **specs** — shardings are built through the launch layer's rule
    table (``launch/mesh.py::spec_for`` / ``tree_shardings``) with the
    logical axis name ``"clients"``, not bespoke ``NamedSharding``
    constructions, so the same arrays place correctly on the production
    ``("pod", "data", "model")`` meshes where ``"clients"`` maps to the
    data axis.

Strategies hold one ``Placement`` (built by ``Strategy.__init__`` from
``make_strategy(..., shard=True)``) and thread it through
``engine.pack_epoch``/``pack_run`` (``pad_clients=placement.n_pad``),
every run builder, aggregation, and ``Strategy.scores_all``.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

HOSP_AXIS = "hosp"


@dataclasses.dataclass(frozen=True)
class Placement:
    """Device placement of the hospital axis.

    ``n_clients`` is the REAL hospital count; ``c_pad >= n_clients`` is the
    array-layout count (a multiple of the mesh size) — rows past
    ``n_clients`` are phantom hospitals.  ``mesh`` is the 1-D ``("hosp",)``
    mesh, or ``None`` when placement is disabled (single device / shard
    off), in which case only the padding contract applies.
    """
    n_clients: int
    c_pad: int
    mesh: object | None = None

    @classmethod
    def make(cls, n_clients: int, enabled: bool = True,
             devices=None) -> "Placement":
        """Build the placement for ``n_clients`` hospitals on the local
        devices.  Disabled (or single-device, or zero-client) placements
        are total no-ops: no mesh, no padding."""
        if devices is None:
            devices = jax.devices()
        if not enabled or n_clients <= 0 or len(devices) < 2:
            return cls(n_clients, max(n_clients, 0), None)
        from jax.sharding import Mesh
        d = len(devices)
        c_pad = -(-n_clients // d) * d
        return cls(n_clients, c_pad, Mesh(np.asarray(devices), (HOSP_AXIS,)))

    # -- predicates ----------------------------------------------------------
    @property
    def enabled(self) -> bool:
        """A mesh exists: hosp-axis arrays are device_put across it."""
        return self.mesh is not None

    @property
    def padded(self) -> bool:
        return self.c_pad > self.n_clients

    @property
    def n_pad(self) -> int:
        """Phantom hospital count appended by ``engine.pack_epoch``."""
        return self.c_pad - self.n_clients

    # -- phantom-hospital masking -------------------------------------------
    def client_weights(self) -> np.ndarray:
        """``[c_pad]`` float32: 1 for real hospitals, 0 for phantoms — the
        weights that make SFLv3 server-gradient averaging and SFLv2/v1
        client syncs provably ignore padding rows."""
        w = np.zeros((self.c_pad,), np.float32)
        w[:self.n_clients] = 1.0
        return w

    # -- shardings (built through the launch-layer rule table) ---------------
    def sharding(self, shape: tuple, axis: int = 0):
        """NamedSharding placing ``shape``'s ``axis`` on the hosp mesh,
        via ``launch.mesh.spec_for`` (divisibility + axis-reuse rules)."""
        from jax.sharding import NamedSharding
        from repro.launch.mesh import spec_for
        axes = tuple("clients" if i == axis else None
                     for i in range(len(shape)))
        return NamedSharding(self.mesh, spec_for(axes, shape, self.mesh))

    def tree_shardings(self, tree, axis: int = 0):
        """Sharding pytree for a stacked-client tree (every leaf carries
        the hospital axis at ``axis``) via ``launch.mesh.tree_shardings``."""
        from repro.launch.mesh import tree_shardings
        axes = jax.tree.map(
            lambda l: tuple("clients" if i == axis else None
                            for i in range(l.ndim)), tree)
        shapes = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)
        return tree_shardings(axes, shapes, self.mesh)

    def leaf_specs(self, tree, axis: int = 0):
        """Per-leaf ``PartitionSpec`` pytree for ``shard_map``: leaves
        carrying the hospital axis at ``axis`` are split on "hosp", the
        rest (rank-0 optimizer step counts, server-shaped leaves) are
        replicated.  Needed where a single prefix spec cannot describe a
        mixed tree (stacked Adam state has a scalar count)."""
        from jax.sharding import PartitionSpec as P

        def one(l):
            if getattr(l, "ndim", 0) > axis and l.shape[axis] == self.c_pad:
                return P(*([None] * axis + [HOSP_AXIS]))
            return P()

        return jax.tree.map(one, tree)

    # -- placement ops -------------------------------------------------------
    def put(self, tree, axis: int = 0):
        """device_put every leaf whose ``shape[axis] == c_pad`` onto the
        hosp mesh; other leaves (server params, schedule arrays, scalars)
        are left alone.  Identity when disabled."""
        if not self.enabled:
            return tree

        def one(x):
            if getattr(x, "ndim", 0) > axis and x.shape[axis] == self.c_pad:
                return jax.device_put(x, self.sharding(x.shape, axis))
            return x

        return jax.tree.map(one, tree)

    def pad_tree(self, tree, mode: str = "edge"):
        """Pad the leading hospital axis of every leaf from ``n_clients``
        to ``c_pad`` rows.  ``mode="edge"`` repeats the last real row
        (finite phantom params keep every model forward well-defined);
        ``mode="zeros"`` appends zero rows.  Identity when not padded."""
        if not self.padded:
            return tree
        pad = self.n_pad

        def one(x):
            if getattr(x, "ndim", 0) < 1 or x.shape[0] != self.n_clients:
                return x
            import jax.numpy as jnp
            if mode == "edge":
                tail = jnp.broadcast_to(x[-1:], (pad, *x.shape[1:]))
            else:
                tail = jnp.zeros((pad, *x.shape[1:]), x.dtype)
            return jnp.concatenate([jnp.asarray(x), tail], axis=0)

        return jax.tree.map(one, tree)

    def pad_rows(self, x: np.ndarray) -> np.ndarray:
        """Zero-pad a host-side ``[n_clients, ...]`` array to ``c_pad``
        rows (the eval data-stack counterpart of ``pad_tree``)."""
        if not self.padded or x.shape[0] != self.n_clients:
            return x
        return np.concatenate(
            [x, np.zeros((self.n_pad, *x.shape[1:]), x.dtype)], axis=0)


__all__ = ["Placement", "HOSP_AXIS"]
