"""Strategy registry: method grid of the paper's Table 2."""

from repro.core.strategies.base import Strategy
from repro.core.strategies.centralized import Centralized
from repro.core.strategies.federated import FedAvg
from repro.core.strategies.split import SplitLearning
from repro.core.strategies.splitfed import SplitFedV1, SplitFedV2, SplitFedV3


def make_strategy(method: str, adapter, opt_factory, n_clients,
                  transport=None, privacy=None, engine="compiled",
                  drop_remainder=True, shard=False, observe=None,
                  precision="fp32", participation=None, aggregator=None):
    """method: centralized | fl | sl_{ac,am} | sflv{1,2,3}_{ac,am}.

    ``transport`` (repro.wire.Transport) compresses the cut-layer link of
    the SL/SFL family; centralized/FL have no cut layer to compress.
    ``privacy`` (repro.privacy.PrivacyConfig) turns on DP-SGD for any
    method, cut-layer noise for the SL/SFL family, and pairwise-mask
    secure aggregation for FL.

    ``engine`` selects the execution path: ``"compiled"`` (the default;
    repro.core.strategies.engine: whole epochs — and whole multi-epoch
    ``Strategy.run``s — as single XLA programs, scan-over-batches /
    scan-over-rounds / vmap-over-hospitals) or ``"stepwise"`` (legacy,
    one jitted dispatch per mini-batch — kept as the parity oracle).
    Both are numerically equivalent to 1e-5 (tests/test_engine.py);
    transport byte accounting and simulated wire timelines are identical
    under either engine (``wire.simulator.timeline_from_accounting``).
    ``drop_remainder=False`` keeps the final short batch of each hospital
    (pad-and-mask on the compiled path).  ``shard=True`` places the
    hospital axis of every compiled program across the local devices on a
    ``("hosp",)`` mesh (``repro.core.placement``): hospital counts that do
    not divide the device count are padded with zero-weight phantom
    hospitals, so any ``n_clients`` runs on any device count with results
    identical to ``shard=False`` (≤1e-5; no-op on one device or under the
    stepwise oracle).

    ``observe`` (repro.obs.Telemetry, or True for the default spec) taps
    per-round x per-hospital metrics — train loss, grad/update norms,
    FedAvg update cosine, cut-layer activation stats, DP clip fraction —
    inside the compiled programs as extra scan outputs: the whole run
    stays ONE dispatch and params are bit-identical to ``observe=None``.
    Results land on ``strategy.last_run_telemetry``.

    ``precision`` (``"fp32"`` | ``"bf16"``) selects the training compute
    dtype via ``core.partition.cast_adapter``: bf16 forward/backward with
    fp32 master params and fp32 optimizer/aggregation accumulation.
    Evaluation always runs full precision.  bf16 is parity-gated against
    fp32 in tests/test_precision.py (AUROC tolerance, not bitwise — see
    DESIGN.md §13).

    ``participation`` (repro.core.participation.Participation) samples K
    of the N enrolled hospitals each round (fixed-size, Poisson, or an
    explicit schedule) — the compiled whole-run program packs each
    round's cohort into a fixed slot axis (compute scales with K, not N)
    and the RDP accountant composes at the amplified rate.  Compiled
    engine only; ``shard`` and secure aggregation are unsupported with
    it, centralized has no cohort to sample, and the split family
    supports fixed-size cohorts without ``observe``.
    ``Participation(n_global=N, k=N)`` is bit-identical to
    ``participation=None``.

    ``aggregator`` (FL only) replaces the data-size-weighted FedAvg mean
    with a registered ``repro.core.aggregate`` rule — a name
    (``"trimmed_mean"``, ``"coordinate_median"``,
    ``"staleness_discounted"``, ``"hierarchical"``...) or an
    ``Aggregator`` instance; ``None`` keeps the (bit-identical) default.
    """
    from repro.core.partition import cast_adapter
    adapter = cast_adapter(adapter, precision)
    if participation is not None and method == "centralized":
        raise ValueError("centralized pools all hospitals; there is no "
                         "per-round cohort to sample")
    if aggregator is not None and method != "fl":
        raise ValueError("aggregator= selects the FedAvg aggregation rule "
                         f"and applies to fl only, not {method}")
    kw = dict(privacy=privacy, engine=engine,
              drop_remainder=drop_remainder, shard=shard, observe=observe,
              participation=participation)
    if method in ("centralized", "fl"):
        if transport is not None:
            raise ValueError(f"{method} has no cut-layer link for a "
                             "transport codec")
        if privacy is not None and privacy.cut_noise_std > 0:
            raise ValueError(f"{method} has no cut layer to noise")
        if privacy is not None and privacy.secagg and method != "fl":
            raise ValueError("secure aggregation needs federated uploads")
        if method == "centralized":
            kw.pop("participation")
            return Centralized(adapter, opt_factory, n_clients, **kw)
        return FedAvg(adapter, opt_factory, n_clients,
                      aggregator=aggregator, **kw)
    if privacy is not None and privacy.secagg:
        raise ValueError("secure aggregation applies to FL model uploads; "
                         f"{method} ships activations, not updates")
    kind, schedule = method.rsplit("_", 1)
    cls = {"sl": SplitLearning, "sflv1": SplitFedV1,
           "sflv2": SplitFedV2, "sflv3": SplitFedV3}[kind]
    return cls(adapter, opt_factory, n_clients, schedule,
               transport=transport, **kw)


METHODS = ["centralized", "fl", "sl_ac", "sl_am",
           "sflv2_ac", "sflv3_ac", "sflv1_ac"]

__all__ = ["Strategy", "Centralized", "FedAvg", "SplitLearning",
           "SplitFedV1", "SplitFedV2", "SplitFedV3", "make_strategy",
           "METHODS"]
