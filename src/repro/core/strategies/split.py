"""Split learning (paper §1.2/§3.4) with the two training schedules:

* alternate-client (AC): prior art — clients take whole-dataset turns.
* alternate-minibatch (AM): the paper's proposed schedule — mini-batch turns.

Client segments are unique per client and never synchronized (paper: "We do
not use any form of weight synchronization").  The server segment (and its
Adam state) is shared and updated sequentially in schedule order — which is
why the compiled engine runs SL as a single scanned interleave over the
dense schedule array (``repro.core.schedule.schedule_array``) rather than a
vmap over hospitals: vmapping would break the exact sequential Adam
semantics of the shared server segment.
"""

from __future__ import annotations

import numpy as np

from repro.core.schedule import SCHEDULES, schedule_array
from repro.core.strategies.base import (Strategy, EpochLog, make_split_step,
                                        np_batches)


class SplitLearning(Strategy):
    name = "sl"
    _sync_stacked = False     # SFLv2/v1 fold client averaging into the run

    def __init__(self, adapter, opt_factory, n_clients, schedule="ac",
                 transport=None, privacy=None, **kw):
        super().__init__(adapter, opt_factory, n_clients, privacy=privacy,
                         **kw)
        self.schedule = schedule
        self.transport = transport
        self.name = f"sl_{schedule}"
        if self.participation is not None:
            if self.participation.kind != "fixed":
                raise ValueError(
                    "the split family supports fixed-size participation "
                    "only (Participation(k=...)): the shared-server "
                    "schedule needs every slot filled")
            if self.observe is not None:
                raise ValueError("participation with observe is not "
                                 "supported for the split family")

    def _client_tree(self, params):
        t = {"front": params["front"]}
        if self.adapter.nls:
            t["tail"] = params["tail"]
        return t

    def setup(self, key):
        import jax
        keys = jax.random.split(key, self.n_clients)
        if not hasattr(self, "_opt_c"):
            self._opt_c, self._opt_s = self.opt_factory(), self.opt_factory()
            self._step = make_split_step(self.adapter, self._opt_c,
                                         self._opt_s, self.transport,
                                         self.privacy)
        opt_c, opt_s = self._opt_c, self._opt_s
        clients, c_opts = [], []
        server = None
        for k in keys:
            params = self.adapter.init(k)
            ct = self._client_tree(params)
            clients.append(ct)
            c_opts.append(opt_c.init(ct))
            if server is None:
                server = params["middle"]
        return {"clients": clients, "server": server,
                "c_opts": c_opts, "s_opt": opt_s.init(server)}

    def _round_telemetry(self, tel, losses, metrics, sched):
        """Reduce one epoch's schedule-ordered per-step taps."""
        from repro.obs import telemetry as T
        if not len(sched):
            return T.RoundTelemetry(0, {})
        return T.rounds_scheduled(
            tel, np.asarray(losses, np.float64)[None],
            {k: np.asarray(v, np.float64)[None]
             for k, v in metrics.items()},
            np.asarray(sched), self.n_clients)[0]

    def run_epoch(self, state, client_data, rng, batch_size):
        if self.engine == "compiled":
            return self._run_epoch_compiled(state, client_data, rng,
                                            batch_size)
        tel = self._tel
        step = self._step if tel is None else self._get_obs(
            "_step_obs", tel,
            lambda: make_split_step(self.adapter, self._opt_c, self._opt_s,
                                    self.transport, self.privacy, tel))
        batches = [np_batches(d, batch_size, rng, self.drop_remainder)
                   for d in client_data]
        order = SCHEDULES[self.schedule]([len(b) for b in batches])
        losses, loss_w, met_vals = [], [], []
        client_steps = [0] * self.n_clients
        for c, b in order:
            args = (state["clients"][c], state["server"],
                    state["c_opts"][c], state["s_opt"], batches[c][b])
            if self._keyed:
                args = args + (self._next_key(),)
            out = step(*args)
            self._count_dispatch()
            (state["clients"][c], state["server"], state["c_opts"][c],
             state["s_opt"], loss) = out[0], out[1], out[2], out[3], out[4]
            if tel is not None:
                met_vals.append(out[5])
            losses.append(float(loss))
            loss_w.append(len(batches[c][b]["label"]))
            client_steps[c] += 1
            self._dp_account(c, len(client_data[c]["label"]), batch_size)
            if self.transport is not None:
                self.transport.account(self.adapter, batches[c][b])
        if order:
            self._record_wire_epoch(
                next(bs[0] for bs in batches if bs),
                [len(b) for b in batches])
        self._end_of_epoch(state)
        log = EpochLog(losses, len(losses), weights=loss_w,
                       client_steps=client_steps)
        if tel is not None:
            log.telemetry = self._round_telemetry(
                tel, losses,
                {k: [float(m[k]) for m in met_vals]
                 for k in (met_vals[0] if met_vals else {})}, order)
        return state, log

    def _ensure_stacked(self, state):
        """Compiled SL/SFLv2 state keeps the hospital axis stacked BETWEEN
        epochs too — unstacking n_clients x n_leaves every epoch costs more
        host time than the compiled epoch itself.  Under placement the
        stack is padded to the mesh multiple (phantom rows are copies of
        the last real client; the schedule never touches them and syncs
        weight them zero) and placed on the "hosp" mesh."""
        from repro.core.partition import stack_trees
        place = self.placement
        if "stacked_clients" not in state:
            state["stacked_clients"] = place.pad_tree(
                stack_trees(state.pop("clients")))
            state["stacked_c_opts"] = place.pad_tree(
                stack_trees(state.pop("c_opts")))
        state["stacked_clients"] = place.put(state["stacked_clients"])
        state["stacked_c_opts"] = place.put(state["stacked_c_opts"])

    def _run_epoch_compiled(self, state, client_data, rng, batch_size):
        from repro.core.strategies import engine as ENG
        tel = self._tel
        place = self.placement
        with self._span("pack"):
            packed = ENG.pack_epoch(client_data, batch_size, rng,
                                    self.drop_remainder,
                                    pad_clients=place.n_pad)
        sched = schedule_array(self.schedule, packed.n_batches)
        if len(sched) == 0:
            self._end_of_epoch(state)        # SFLv2 still syncs clients
            return state, EpochLog([], 0,
                                   client_steps=[0] * self.n_clients)
        if tel is None:
            if not hasattr(self, "_epoch_c"):
                self._epoch_c = ENG.make_interleaved_epoch(
                    self.adapter, self._opt_c, self._opt_s, self.transport,
                    self.privacy)
            epoch_fn = self._epoch_c
        else:
            epoch_fn = self._get_obs(
                "_epoch_obs_c", tel,
                lambda: ENG.make_interleaved_epoch(
                    self.adapter, self._opt_c, self._opt_s, self.transport,
                    self.privacy, tel))
        key_idx = (self._take_key_indices(len(sched)) if self._keyed
                   else np.zeros((len(sched),), np.uint32))
        self._ensure_stacked(state)
        with self._span("dispatch"):
            out = epoch_fn(
                state["stacked_clients"], state["server"],
                state["stacked_c_opts"], state["s_opt"],
                place.put(packed.batches), place.put(packed.ex_weights),
                sched, key_idx, self._privacy_base_key())
        self._count_dispatch()
        (state["stacked_clients"], state["server"],
         state["stacked_c_opts"], state["s_opt"], losses) = out[:5]
        flat, loss_w = ENG.scheduled_log(losses, sched, packed)
        # the interleave program's output sharding is compiler-chosen:
        # re-place so between-epoch state is always on the hosp mesh
        state["stacked_clients"] = place.put(state["stacked_clients"])
        state["stacked_c_opts"] = place.put(state["stacked_c_opts"])
        self._account_compiled(packed, batch_size)
        self._end_of_epoch(state)
        log = EpochLog(flat, len(flat), weights=loss_w,
                       client_steps=list(
                           packed.n_batches[:self.n_clients]))
        if tel is not None:
            log.telemetry = self._round_telemetry(
                tel, np.asarray(losses),
                {k: np.asarray(v) for k, v in out[5].items()}, sched)
        return state, log

    @property
    def _whole_run(self):
        return True

    def _run_compiled(self, state, client_data, rng, batch_size, n_epochs):
        from repro.core.strategies import engine as ENG
        if ENG.empty_run(client_data, batch_size, self.drop_remainder):
            return None                        # empty run: per-epoch path
        if self.participation is not None:
            return self._run_participation(state, client_data, rng,
                                           batch_size, n_epochs)
        tel = self._tel
        place = self.placement
        with self._span("pack"):
            batches, packed = ENG.pack_run(client_data, batch_size, rng,
                                           n_epochs, self.drop_remainder,
                                           pad_clients=place.n_pad)
        sched = schedule_array(self.schedule, packed.n_batches)
        sync_w = place.client_weights() if place.padded else None
        if tel is None:
            if not hasattr(self, "_run_c"):
                self._run_c = ENG.make_interleaved_run(
                    self.adapter, self._opt_c, self._opt_s, self.transport,
                    self.privacy, sync_clients=self._sync_stacked,
                    client_weights=sync_w)
            run_fn = self._run_c
        else:
            run_fn = self._get_obs(
                "_run_obs_c", tel,
                lambda: ENG.make_interleaved_run(
                    self.adapter, self._opt_c, self._opt_s, self.transport,
                    self.privacy, sync_clients=self._sync_stacked,
                    client_weights=sync_w, telemetry=tel))
        key_idx = np.stack([
            self._take_key_indices(len(sched)) if self._keyed
            else np.zeros((len(sched),), np.uint32)
            for _ in range(n_epochs)])
        self._ensure_stacked(state)
        args = (state["stacked_clients"], state["server"],
                state["stacked_c_opts"], state["s_opt"],
                place.put(batches, axis=1), place.put(packed.ex_weights),
                sched, key_idx, self._privacy_base_key())
        with self._span("dispatch"):
            out = run_fn(*args)
        self._count_dispatch()
        self._last_run_invocation = (run_fn, ENG.abstract_args(args))
        (state["stacked_clients"], state["server"],
         state["stacked_c_opts"], state["s_opt"], losses) = out[:5]
        self._run_calls = getattr(self, "_run_calls", 0) + 1
        state["stacked_clients"] = place.put(state["stacked_clients"])
        state["stacked_c_opts"] = place.put(state["stacked_c_opts"])
        losses = np.asarray(losses)
        logs = []
        for e in range(n_epochs):
            flat, loss_w = ENG.scheduled_log(losses[e], sched, packed)
            logs.append(EpochLog(flat, len(flat), weights=loss_w,
                                 client_steps=list(
                                     packed.n_batches[:self.n_clients])))
        if tel is not None:
            from repro.obs import telemetry as T
            rounds = T.rounds_scheduled(
                tel, losses, {k: np.asarray(v) for k, v in out[5].items()},
                sched, self.n_clients)
            for log, r in zip(logs, rounds):
                log.telemetry = r
        self._account_compiled(packed, batch_size, n_epochs)
        return state, logs

    def _run_participation(self, state, client_data, rng, batch_size,
                           n_epochs):
        """Whole participating SL/SFLv2 run: per round the full-N virtual
        schedule is filtered to the K sampled hospitals (relative order
        preserved) and padded to a fixed step count; per-step keys carry
        the VIRTUAL full-N schedule position, so a hospital's noise draws
        depend only on (round, hospital) and ``Participation(k=N)``
        reproduces ``participation=None`` exactly."""
        from repro.core.strategies import engine as ENG
        if self._tel is not None:
            raise ValueError("participation with observe is not supported "
                             "for the split family")
        part = self.participation
        with self._span("pack"):
            batches, pack = ENG.pack_participation_run(
                client_data, batch_size, rng, n_epochs, part,
                self.drop_remainder)
        nbs = pack.n_batches
        full_sched = schedule_array(self.schedule, nbs)
        S_N = len(full_sched)
        # per-round schedule rows (slot, batch, valid) + virtual key pos
        rounds = []
        for e in range(n_epochs):
            gid = pack.slot_gid[e]
            slot_of = {int(g): s for s, g in enumerate(gid) if g >= 0}
            rounds.append([(slot_of[int(c)], int(b), p)
                           for p, (c, b) in enumerate(full_sched)
                           if int(c) in slot_of])
        steps_max = max((len(r) for r in rounds), default=0)
        if steps_max == 0:
            return None
        sched = np.zeros((n_epochs, steps_max, 3), np.int32)
        key_idx = np.zeros((n_epochs, steps_max), np.uint32)
        base0 = self._key_step
        for e, rows in enumerate(rounds):
            for t, (slot, b, p) in enumerate(rows):
                sched[e, t] = (slot, b, 1)
                if self._keyed:
                    key_idx[e, t] = base0 + 1 + e * S_N + p
        if self._keyed:
            self._key_step += n_epochs * S_N
        if not hasattr(self, "_run_part_c"):
            self._run_part_c = ENG.make_interleaved_run_participation(
                self.adapter, self._opt_c, self._opt_s, self.n_clients,
                self.transport, self.privacy,
                sync_clients=self._sync_stacked)
        run_fn = self._run_part_c
        self._ensure_stacked(state)
        args = (state["stacked_clients"], state["server"],
                state["stacked_c_opts"], state["s_opt"], batches,
                pack.ex_weights, sched, key_idx,
                self._privacy_base_key(), pack.slot_gid)
        with self._span("dispatch"):
            out = run_fn(*args)
        self._count_dispatch()
        self._last_run_invocation = (run_fn, ENG.abstract_args(args))
        (state["stacked_clients"], state["server"],
         state["stacked_c_opts"], state["s_opt"], losses) = out[:5]
        self._run_calls = getattr(self, "_run_calls", 0) + 1
        losses = np.asarray(losses)
        logs = []
        for e, rows in enumerate(rounds):
            gid = pack.slot_gid[e]
            flat = [float(x) for x in losses[e, :len(rows)]]
            loss_w = [pack.step_examples[int(gid[slot])][b]
                      for slot, b, _p in rows]
            csteps = [0] * pack.n_global
            for slot, _b, _p in rows:
                csteps[int(gid[slot])] += 1
            logs.append(EpochLog(flat, len(flat), weights=loss_w,
                                 client_steps=csteps))
        # amplified RDP: every hospital composes every round at rate K/N
        # over the steps it runs when sampled
        self._last_part_nbs = list(nbs)
        for g in range(pack.n_global):
            if nbs[g]:
                self._dp_account(g, pack.n_samples[g], batch_size,
                                 count=nbs[g] * n_epochs,
                                 q_scale=part.rate)
        # wire: only sampled clients' transfers exist, per round
        if self.transport is not None:
            example = {k: v[0, 0, 0] for k, v in batches.items()}
            for e in range(n_epochs):
                ids = np.flatnonzero(pack.part_mask[e])
                counts = [0] * pack.n_global
                for g in ids:
                    g = int(g)
                    counts[g] = nbs[g]
                    for m, n_steps in zip(
                            *np.unique(pack.step_examples[g],
                                       return_counts=True)):
                        b = (example if m == pack.batch_size
                             else {k: v[:m] for k, v in example.items()})
                        self.transport.account(self.adapter, b,
                                               count=int(n_steps))
                self._record_wire_epoch(example, counts, client_set=ids)
        return state, logs

    def _account_compiled(self, packed, batch_size, n_epochs=1):
        """Analytic accounting for the compiled path: the DP accountant
        composes each hospital's step count in one call, and the transport
        meters each step at its TRUE batch shape — full batches in one
        ``count=`` call, a kept remainder batch (``drop_remainder=False``)
        at its short shape, exactly the bytes the stepwise per-step path
        meters — times ``n_epochs`` for a whole-run program."""
        example = {k: v[0, 0] for k, v in packed.batches.items()}
        for c, nb in enumerate(packed.n_batches):
            if not nb:
                continue
            self._dp_account(c, packed.n_samples[c], batch_size,
                             count=nb * n_epochs)
            if self.transport is not None:
                for m, n_steps in zip(*np.unique(packed.step_examples[c],
                                                 return_counts=True)):
                    b = (example if m == packed.batch_size
                         else {k: v[:m] for k, v in example.items()})
                    self.transport.account(self.adapter, b,
                                           count=int(n_steps) * n_epochs)
        for _ in range(n_epochs):
            self._record_wire_epoch(example, packed.n_batches)

    def _record_wire_epoch(self, example_batch, n_batches,
                           client_set=None):
        """The analytic->timeline bridge hook: hand the transport this
        epoch's schedule signature so ``wire.simulator`` can expand the
        summary accounting back into per-step timelines.  Placement
        phantom rows (zero batches) are sliced off — the recorded
        signature is placement-independent.  ``client_set`` marks a
        participating round's sampled clients (unsampled entries are
        zero, so the expansion emits no events for them)."""
        n_batches = list(n_batches)[:self.n_clients]
        if self.transport is None or not sum(n_batches):
            return
        self.transport.record_epoch(self.adapter, example_batch,
                                    self.name.rsplit("_", 1)[0],
                                    self.schedule, n_batches,
                                    client_set=client_set)

    def _end_of_epoch(self, state):
        pass

    def params_for_eval(self, state, client_idx):
        if "stacked_clients" in state:           # compiled-engine layout
            from repro.core.partition import tree_take
            ct = tree_take(state["stacked_clients"], client_idx)
        else:
            ct = state["clients"][client_idx]
        p = {"front": ct["front"], "middle": state["server"]}
        if self.adapter.nls:
            p["tail"] = ct["tail"]
        return p
