"""Split learning (paper §1.2/§3.4) with the two training schedules:

* alternate-client (AC): prior art — clients take whole-dataset turns.
* alternate-minibatch (AM): the paper's proposed schedule — mini-batch turns.

Client segments are unique per client and never synchronized (paper: "We do
not use any form of weight synchronization").  The server segment (and its
Adam state) is shared and updated sequentially in schedule order.
"""

from __future__ import annotations


from repro.core.schedule import SCHEDULES
from repro.core.strategies.base import (Strategy, EpochLog, make_split_step,
                                        np_batches)


class SplitLearning(Strategy):
    name = "sl"

    def __init__(self, adapter, opt_factory, n_clients, schedule="ac",
                 transport=None, privacy=None):
        super().__init__(adapter, opt_factory, n_clients, privacy=privacy)
        self.schedule = schedule
        self.transport = transport
        self.name = f"sl_{schedule}"

    def _client_tree(self, params):
        t = {"front": params["front"]}
        if self.adapter.nls:
            t["tail"] = params["tail"]
        return t

    def setup(self, key):
        import jax
        keys = jax.random.split(key, self.n_clients)
        if not hasattr(self, "_opt_c"):
            self._opt_c, self._opt_s = self.opt_factory(), self.opt_factory()
            self._step = make_split_step(self.adapter, self._opt_c,
                                         self._opt_s, self.transport,
                                         self.privacy)
        opt_c, opt_s = self._opt_c, self._opt_s
        clients, c_opts = [], []
        server = None
        for k in keys:
            params = self.adapter.init(k)
            ct = self._client_tree(params)
            clients.append(ct)
            c_opts.append(opt_c.init(ct))
            if server is None:
                server = params["middle"]
        return {"clients": clients, "server": server,
                "c_opts": c_opts, "s_opt": opt_s.init(server)}

    def run_epoch(self, state, client_data, rng, batch_size):
        batches = [np_batches(d, batch_size, rng) for d in client_data]
        order = SCHEDULES[self.schedule]([len(b) for b in batches])
        losses = []
        for c, b in order:
            args = (state["clients"][c], state["server"],
                    state["c_opts"][c], state["s_opt"], batches[c][b])
            if self._keyed:
                args = args + (self._next_key(),)
            (state["clients"][c], state["server"], state["c_opts"][c],
             state["s_opt"], loss) = self._step(*args)
            losses.append(float(loss))
            self._dp_account(c, len(client_data[c]["label"]), batch_size)
            if self.transport is not None:
                self.transport.account(self.adapter, batches[c][b])
        self._end_of_epoch(state)
        return state, EpochLog(losses, len(losses))

    def _end_of_epoch(self, state):
        pass

    def params_for_eval(self, state, client_idx):
        p = {"front": state["clients"][client_idx]["front"],
             "middle": state["server"]}
        if self.adapter.nls:
            p["tail"] = state["clients"][client_idx]["tail"]
        return p
