"""SplitFed variants.

* SFLv2 (Thapa et al.): server segment trained SEQUENTIALLY like SL, but the
  client segments are synchronized at the end of each epoch by fed-averaging.
* SFLv3 (THE PAPER'S PROPOSAL, Algorithm 1): client segments stay unique
  (like SL), while the server segment is updated with the weighted AVERAGE of
  per-client gradients computed in parallel — removing the sequential
  catastrophic-forgetting bias of SL/SFLv2 server training.
* SFLv1 (bonus; the paper excluded it for hardware reasons): SFLv3's parallel
  server + fed-averaged client segments each round.

SFLv3 implementation note (recorded in DESIGN.md): Algorithm 1 as printed
concatenates a full epoch of activations and performs one server update per
round; trained with Adam@1e-4 for 10 rounds that cannot reach the reported
AUROC. We use the batch-synchronous reading (one averaged server update per
mini-batch step, "same as SplitFedv1" per the paper's own description),
which matches the reported training times and accuracies.

Under the compiled engine SFLv2 inherits SL's scanned interleave (its server
is sequential too); SFLv3/v1 scan over synchronous steps with the vmapped
per-client step inside and the wrap-around batch index precomputed as a
dense ``[steps, n_clients]`` array.
"""

from __future__ import annotations

import numpy as np

from repro.core.partition import stack_trees
from repro.core.strategies.base import (EpochLog, make_sflv3_step,
                                        np_batches, tree_mean)
from repro.core.strategies.split import SplitLearning


class SplitFedV2(SplitLearning):
    """Sequential server training + end-of-epoch client averaging."""

    _sync_stacked = True      # fold the client averaging into the run scan

    def __init__(self, adapter, opt_factory, n_clients, schedule="ac",
                 transport=None, privacy=None, **kw):
        super().__init__(adapter, opt_factory, n_clients, schedule,
                         transport, privacy, **kw)
        self.name = f"sflv2_{schedule}"

    def _end_of_epoch(self, state):
        if "stacked_clients" in state:           # compiled-engine layout
            from repro.core.strategies.engine import stacked_mean_sync
            place = self.placement
            state["stacked_clients"] = stacked_mean_sync(
                state["stacked_clients"],
                place.client_weights() if place.padded else None)
            return
        avg = tree_mean(state["clients"])
        state["clients"] = [avg for _ in range(self.n_clients)]


class SplitFedV3(SplitLearning):
    """Unique clients + gradient-averaged parallel server updates (Alg. 1)."""

    def __init__(self, adapter, opt_factory, n_clients, schedule="ac",
                 transport=None, privacy=None, **kw):
        super().__init__(adapter, opt_factory, n_clients, schedule,
                         transport, privacy, **kw)
        if not self.drop_remainder:
            raise ValueError(
                "SplitFedV3/V1 are batch-synchronous: every client ships a "
                "same-shaped batch each step, so drop_remainder=False is "
                "not representable; use drop_remainder=True")
        self.name = f"sflv3_{schedule}"

    def setup(self, key):
        import jax
        keys = jax.random.split(key, self.n_clients)
        if not hasattr(self, "_opt_c"):
            self._opt_c, self._opt_s = self.opt_factory(), self.opt_factory()
            self._step3 = make_sflv3_step(self.adapter, self._opt_c,
                                          self._opt_s, self.n_clients,
                                          self.transport, self.privacy)
        opt_c, opt_s = self._opt_c, self._opt_s
        clients, server = [], None
        for k in keys:
            params = self.adapter.init(k)
            clients.append(self._client_tree(params))
            if server is None:
                server = params["middle"]
        stacked = stack_trees(clients)
        if self.engine == "compiled":
            # placement layout: phantom rows (copies of the last real
            # client) reach the mesh multiple; their batches are zeros and
            # their weight in every average is zero
            stacked = self.placement.put(self.placement.pad_tree(stacked))
        return {"stacked_clients": stacked, "server": server,
                "c_opt": opt_c.init(stacked), "s_opt": opt_s.init(server)}

    def _check_batches(self, n_batches, batch_size):
        empty = [c for c, nb in enumerate(n_batches) if not nb]
        if empty:
            # batch-synchronous SFLv3 averages over ALL clients every step;
            # a client without a single full batch cannot participate
            raise ValueError(
                f"clients {empty} have fewer than batch_size="
                f"{batch_size} train samples; SplitFedV3 needs at least "
                "one batch per client")

    def _sync_round_telemetry(self, tel, losses, metrics):
        """Reduce one epoch's ``[S, C]`` synchronous-step taps."""
        from repro.obs import telemetry as T
        losses = np.asarray(losses, np.float64)
        if not losses.size:
            return T.RoundTelemetry(0, {})
        return T.rounds_sync(
            tel, losses[None],
            {k: np.asarray(v, np.float64)[None]
             for k, v in metrics.items()}, self.n_clients)[0]

    def run_epoch(self, state, client_data, rng, batch_size):
        if self.engine == "compiled":
            return self._run_epoch_compiled(state, client_data, rng,
                                            batch_size)
        tel = self._tel
        step3 = self._step3 if tel is None else self._get_obs(
            "_step3_obs", tel,
            lambda: make_sflv3_step(self.adapter, self._opt_c, self._opt_s,
                                    self.n_clients, self.transport,
                                    self.privacy, telemetry=tel))
        batches = [np_batches(d, batch_size, rng) for d in client_data]
        self._check_batches([len(b) for b in batches], batch_size)
        steps = max(len(b) for b in batches)
        losses, step_loss_rows, met_vals = [], [], []
        for s in range(steps):
            # clients that exhausted their data wrap around (all data is
            # seen once per epoch; the server always averages n clients)
            stacked_batch = stack_trees(
                [batches[c][s % len(batches[c])] for c in
                 range(self.n_clients)])
            args = (state["stacked_clients"], state["server"],
                    state["c_opt"], state["s_opt"], stacked_batch)
            if self._keyed:
                args = args + (self._next_key(),)
            out = step3(*args)
            self._count_dispatch()
            (state["stacked_clients"], state["server"], state["c_opt"],
             state["s_opt"], step_losses) = out[:5]
            if tel is not None:
                step_loss_rows.append(np.asarray(step_losses))
                met_vals.append(out[5])
            losses.extend(np.asarray(step_losses).tolist())
            for c in range(self.n_clients):
                # wrap-around resampling included: every client is touched
                self._dp_account(c, len(client_data[c]["label"]),
                                 batch_size)
            if self.transport is not None:
                # every client transfers every step (wrap-around included)
                for c in range(self.n_clients):
                    self.transport.account(self.adapter,
                                           batches[c][s % len(batches[c])])
        self._record_wire_epoch(batches[0][0], [len(b) for b in batches])
        self._end_of_epoch(state)
        log = EpochLog(losses, steps,
                       client_steps=[steps] * self.n_clients)
        if tel is not None:
            log.telemetry = self._sync_round_telemetry(
                tel, np.stack(step_loss_rows),
                {k: np.stack([np.asarray(m[k]) for m in met_vals])
                 for k in (met_vals[0] if met_vals else {})})
        return state, log

    def _run_epoch_compiled(self, state, client_data, rng, batch_size):
        from repro.core.strategies import engine as ENG
        tel = self._tel
        place = self.placement
        with self._span("pack"):
            packed = ENG.pack_epoch(client_data, batch_size, rng, True,
                                    pad_clients=place.n_pad)
        self._check_batches(packed.n_batches[:self.n_clients], batch_size)
        steps = packed.nb_max
        if tel is None:
            if not hasattr(self, "_epoch_c"):
                self._epoch_c = ENG.make_sflv3_epoch(
                    self.adapter, self._opt_c, self._opt_s, place.c_pad,
                    self.transport, self.privacy,
                    client_weights=(place.client_weights() if place.padded
                                    else None),
                    placement=place)
            epoch_fn = self._epoch_c
        else:
            epoch_fn = self._get_obs(
                "_epoch_obs_c", tel,
                lambda: ENG.make_sflv3_epoch(
                    self.adapter, self._opt_c, self._opt_s, place.c_pad,
                    self.transport, self.privacy,
                    client_weights=(place.client_weights() if place.padded
                                    else None),
                    placement=place, telemetry=tel))
        b_idx = np.stack([[s % nb if nb else 0 for nb in packed.n_batches]
                          for s in range(steps)]).astype(np.int32)
        key_idx = (self._take_key_indices(steps) if self._keyed
                   else np.zeros((steps,), np.uint32))
        batches = place.put(packed.batches)
        sc = place.put(state["stacked_clients"])
        c_opt = place.put(state["c_opt"])
        with self._span("dispatch"):
            out = epoch_fn(
                sc, state["server"], c_opt, state["s_opt"], batches,
                place.put(b_idx, axis=1), key_idx,
                self._privacy_base_key())
        self._count_dispatch()
        (state["stacked_clients"], state["server"], state["c_opt"],
         state["s_opt"], losses) = out[:5]
        flat = np.asarray(losses)[:, :self.n_clients].reshape(-1).tolist()
        self._account_v3(packed, batch_size)
        self._end_of_epoch(state)
        log = EpochLog(flat, steps,
                       client_steps=[steps] * self.n_clients)
        if tel is not None:
            log.telemetry = self._sync_round_telemetry(
                tel, np.asarray(losses),
                {k: np.asarray(v) for k, v in out[5].items()})
        return state, log

    def _account_v3(self, packed, batch_size, n_epochs=1):
        """Analytic accounting: every client is touched every synchronous
        step (wrap-around resampling included), so the per-epoch count is
        simply ``steps = nb_max`` for DP and transport alike."""
        steps = packed.nb_max
        example = {k: v[0, 0] for k, v in packed.batches.items()}
        for c in range(self.n_clients):
            self._dp_account(c, packed.n_samples[c], batch_size,
                             count=steps * n_epochs)
            if self.transport is not None:
                self.transport.account(self.adapter, example,
                                       count=steps * n_epochs)
        for _ in range(n_epochs):
            self._record_wire_epoch(example, packed.n_batches)

    @property
    def _whole_run(self):
        return True

    def _run_compiled(self, state, client_data, rng, batch_size, n_epochs):
        from repro.core.strategies import engine as ENG
        if self.participation is not None:
            return self._run_participation(state, client_data, rng,
                                           batch_size, n_epochs)
        tel = self._tel
        place = self.placement
        with self._span("pack"):
            batches, packed = ENG.pack_run(client_data, batch_size, rng,
                                           n_epochs, True,
                                           pad_clients=place.n_pad)
        self._check_batches(packed.n_batches[:self.n_clients], batch_size)
        steps = packed.nb_max
        if tel is None:
            if not hasattr(self, "_run3_c"):
                self._run3_c = ENG.make_sflv3_run(
                    self.adapter, self._opt_c, self._opt_s, place.c_pad,
                    self.transport, self.privacy,
                    sync_clients=self._sync_stacked,
                    client_weights=(place.client_weights() if place.padded
                                    else None),
                    placement=place)
            run_fn = self._run3_c
        else:
            run_fn = self._get_obs(
                "_run3_obs_c", tel,
                lambda: ENG.make_sflv3_run(
                    self.adapter, self._opt_c, self._opt_s, place.c_pad,
                    self.transport, self.privacy,
                    sync_clients=self._sync_stacked,
                    client_weights=(place.client_weights() if place.padded
                                    else None),
                    placement=place, telemetry=tel))
        b_idx = np.stack([[s % nb if nb else 0 for nb in packed.n_batches]
                          for s in range(steps)]).astype(np.int32)
        key_idx = np.stack([
            self._take_key_indices(steps) if self._keyed
            else np.zeros((steps,), np.uint32) for _ in range(n_epochs)])
        args = (place.put(state["stacked_clients"]), state["server"],
                place.put(state["c_opt"]), state["s_opt"],
                place.put(batches, axis=1), place.put(b_idx, axis=1),
                key_idx, self._privacy_base_key())
        with self._span("dispatch"):
            out = run_fn(*args)
        self._count_dispatch()
        self._last_run_invocation = (run_fn, ENG.abstract_args(args))
        (state["stacked_clients"], state["server"], state["c_opt"],
         state["s_opt"], losses) = out[:5]
        self._run_calls = getattr(self, "_run_calls", 0) + 1
        losses = np.asarray(losses)
        logs = [EpochLog(losses[e, :, :self.n_clients].reshape(-1).tolist(),
                         steps, client_steps=[steps] * self.n_clients)
                for e in range(n_epochs)]
        if tel is not None:
            from repro.obs import telemetry as T
            rounds = T.rounds_sync(
                tel, losses, {k: np.asarray(v) for k, v in out[5].items()},
                self.n_clients)
            for log, r in zip(logs, rounds):
                log.telemetry = r
        self._account_v3(packed, batch_size, n_epochs)
        return state, logs

    def _run_participation(self, state, client_data, rng, batch_size,
                           n_epochs):
        """Whole participating SplitFedv3/v1 run: K sampled hospitals step
        batch-synchronously each round; client segments and optimizer rows
        are gathered/scattered out of the persistent ``[N, ...]`` stacks
        by global id inside the fused program.

        The steps axis is fixed at the GLOBAL max batch count so the grid
        never reshapes; rounds whose sampled cohort is shallower mask the
        tail steps out.  Per-step keys use the full-N virtual grid
        (round-major), so ``Participation(k=N)`` reproduces
        ``participation=None`` exactly.  The RDP accountant composes
        every hospital every round at the amplified rate over the GLOBAL
        step count — a (documented) conservative bound when a sampled
        cohort runs fewer steps."""
        from repro.core.strategies import engine as ENG
        if self._tel is not None:
            raise ValueError("participation with observe is not supported "
                             "for the split family")
        part = self.participation
        with self._span("pack"):
            batches, pack = ENG.pack_participation_run(
                client_data, batch_size, rng, n_epochs, part, True)
        nbs = pack.n_batches
        self._check_batches(nbs, batch_size)
        NB_N, S = pack.nb_max, pack.n_slots
        b_idx = np.zeros((n_epochs, NB_N, S), np.int32)
        step_valid = np.zeros((n_epochs, NB_N), np.float32)
        key_idx = np.zeros((n_epochs, NB_N), np.uint32)
        base0 = self._key_step
        real_steps = []
        for e in range(n_epochs):
            gid = pack.slot_gid[e]
            rs = max(nbs[int(g)] for g in gid if g >= 0)
            real_steps.append(rs)
            step_valid[e, :rs] = 1.0
            for s in range(S):
                g = int(gid[s])
                if g >= 0 and nbs[g]:
                    b_idx[e, :, s] = np.arange(NB_N) % nbs[g]
            if self._keyed:
                key_idx[e] = base0 + 1 + e * NB_N + np.arange(NB_N)
        if self._keyed:
            self._key_step += n_epochs * NB_N
        if not hasattr(self, "_run3_part_c"):
            self._run3_part_c = ENG.make_sflv3_run_participation(
                self.adapter, self._opt_c, self._opt_s, S, self.n_clients,
                self.transport, self.privacy,
                sync_clients=self._sync_stacked)
        run_fn = self._run3_part_c
        args = (state["stacked_clients"], state["server"], state["c_opt"],
                state["s_opt"], batches, b_idx, key_idx, step_valid,
                self._privacy_base_key(), pack.slot_gid)
        with self._span("dispatch"):
            out = run_fn(*args)
        self._count_dispatch()
        self._last_run_invocation = (run_fn, ENG.abstract_args(args))
        (state["stacked_clients"], state["server"], state["c_opt"],
         state["s_opt"], losses) = out[:5]
        self._run_calls = getattr(self, "_run_calls", 0) + 1
        losses = np.asarray(losses)
        logs = []
        for e in range(n_epochs):
            rs = real_steps[e]
            sampled = set(int(g) for g in pack.slot_gid[e] if g >= 0)
            csteps = [rs if g in sampled else 0
                      for g in range(pack.n_global)]
            logs.append(EpochLog(losses[e, :rs, :].reshape(-1).tolist(),
                                 rs, client_steps=csteps))
        # amplified RDP at the global step count (conservative when a
        # sampled cohort runs fewer); wire sees sampled clients only
        self._last_part_nbs = [NB_N] * pack.n_global
        for g in range(pack.n_global):
            self._dp_account(g, pack.n_samples[g], batch_size,
                             count=NB_N * n_epochs, q_scale=part.rate)
        if self.transport is not None:
            example = {k: v[0, 0, 0] for k, v in batches.items()}
            for e in range(n_epochs):
                ids = np.flatnonzero(pack.part_mask[e])
                rs = real_steps[e]
                counts = [0] * pack.n_global
                for g in ids:
                    counts[int(g)] = rs
                    self.transport.account(self.adapter, example, count=rs)
                self._record_wire_epoch(example, counts, client_set=ids)
        return state, logs

    def _end_of_epoch(self, state):
        pass

    def params_for_eval(self, state, client_idx):
        from repro.core.partition import tree_take
        ct = tree_take(state["stacked_clients"], client_idx)
        p = {"front": ct["front"], "middle": state["server"]}
        if self.adapter.nls:
            p["tail"] = ct["tail"]
        return p


class SplitFedV1(SplitFedV3):
    """Parallel server (like v3) + fed-averaged clients each round."""

    _sync_stacked = True

    def __init__(self, adapter, opt_factory, n_clients, schedule="ac",
                 transport=None, privacy=None, **kw):
        super().__init__(adapter, opt_factory, n_clients, schedule,
                         transport, privacy, **kw)
        self.name = f"sflv1_{schedule}"

    def _end_of_epoch(self, state):
        from repro.core.strategies.engine import stacked_mean_sync
        place = self.placement
        state["stacked_clients"] = stacked_mean_sync(
            state["stacked_clients"],
            place.client_weights() if place.padded else None)
