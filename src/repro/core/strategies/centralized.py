"""Centralized training — the paper's benchmark upper bound (§3.6)."""

from __future__ import annotations

import numpy as np

from repro.core.strategies.base import (Strategy, EpochLog, make_full_step,
                                        np_batches)


class Centralized(Strategy):
    name = "centralized"
    shared_eval_params = True
    # the per-round epsilon series composes at the pooled sampling rate:
    # every hospital's records sit in the pooled training set
    _eps_pooled = True

    def setup(self, key):
        params = self.adapter.init(key)
        if not hasattr(self, "_opt"):
            self._opt = self.opt_factory()
            self._step = make_full_step(self.adapter, self._opt,
                                        self.privacy)
        return {"params": params, "opt": self._opt.init(params)}

    def _round_telemetry(self, tel, losses, metrics):
        """Reduce one pooled epoch's per-step taps (the centralized
        trainer is a single pooled 'hospital')."""
        from repro.obs import telemetry as T
        nb = len(losses)
        if nb == 0:
            return T.RoundTelemetry(0, {})
        arr = np.asarray(losses, np.float64)[None, None]
        mets = {k: np.asarray(v, np.float64)[None, None]
                for k, v in metrics.items()}
        return T.rounds_client_major(tel, arr, mets,
                                     np.ones((1, nb), bool), 1)[0]

    def run_epoch(self, state, client_data, rng, batch_size):
        pooled = {k: np.concatenate([d[k] for d in client_data])
                  for k in client_data[0]}
        if self.engine == "compiled":
            return self._run_epoch_compiled(state, pooled, rng, batch_size)
        tel = self._tel
        step = self._step if tel is None else self._get_obs(
            "_step_obs", tel,
            lambda: make_full_step(self.adapter, self._opt, self.privacy,
                                   tel))
        n_pooled = len(pooled["label"])
        losses, weights, met_vals = [], [], []
        for batch in np_batches(pooled, batch_size, rng,
                                self.drop_remainder):
            args = ((state["params"], state["opt"], batch,
                     self._next_key()) if self._keyed
                    else (state["params"], state["opt"], batch))
            out = step(*args)
            self._count_dispatch()
            state["params"], state["opt"], loss = out[0], out[1], out[2]
            if tel is not None:
                met_vals.append(out[3])
            losses.append(float(loss))
            weights.append(len(batch["label"]))
            # centralized DP: every hospital's records sit in the pooled
            # set, so each carries the same pooled-rate guarantee
            for ci in range(self.n_clients):
                self._dp_account(ci, n_pooled, batch_size)
        log = EpochLog(losses, len(losses), weights=weights)
        if tel is not None:
            log.telemetry = self._round_telemetry(
                tel, losses,
                {k: [float(m[k]) for m in met_vals]
                 for k in (met_vals[0] if met_vals else {})})
        return state, log

    def _run_epoch_compiled(self, state, pooled, rng, batch_size):
        from repro.core.strategies import engine as ENG
        tel = self._tel
        with self._span("pack"):
            packed = ENG.pack_epoch([pooled], batch_size, rng,
                                    self.drop_remainder)
        nb = packed.n_batches[0]
        if nb == 0:
            return state, EpochLog([], 0)
        if tel is None:
            if not hasattr(self, "_epoch_c"):
                self._epoch_c = ENG.make_seq_epoch(self.adapter, self._opt,
                                                   self.privacy)
            epoch_fn = self._epoch_c
        else:
            epoch_fn = self._get_obs(
                "_epoch_obs_c", tel,
                lambda: ENG.make_seq_epoch(self.adapter, self._opt,
                                           self.privacy, tel))
        key_idx = np.zeros((packed.nb_max,), np.uint32)
        if self._keyed:
            key_idx[:nb] = self._take_key_indices(nb)
        batches = {k: v[0] for k, v in packed.batches.items()}
        ex_w = None if packed.ex_weights is None else packed.ex_weights[0]
        with self._span("dispatch"):
            out = epoch_fn(
                state["params"], state["opt"], batches, packed.mask[0],
                ex_w, key_idx, self._privacy_base_key())
        self._count_dispatch()
        state["params"], state["opt"], losses = out[0], out[1], out[2]
        flat = [float(x) for x in np.asarray(losses)[:nb]]
        for ci in range(self.n_clients):
            self._dp_account(ci, packed.n_samples[0], batch_size, count=nb)
        log = EpochLog(flat, nb, weights=packed.step_examples[0])
        if tel is not None:
            log.telemetry = self._round_telemetry(
                tel, flat,
                {k: np.asarray(v)[:nb] for k, v in out[3].items()})
        return state, log

    @property
    def _whole_run(self):
        return True

    def _run_compiled(self, state, client_data, rng, batch_size, n_epochs):
        from repro.core.strategies import engine as ENG
        pooled = {k: np.concatenate([d[k] for d in client_data])
                  for k in client_data[0]}
        if ENG.empty_run([pooled], batch_size, self.drop_remainder):
            return None
        tel = self._tel
        with self._span("pack"):
            batches, packed = ENG.pack_run([pooled], batch_size, rng,
                                           n_epochs, self.drop_remainder)
        nb = packed.n_batches[0]
        if tel is None:
            if not hasattr(self, "_run_c"):
                self._run_c = ENG.make_seq_run(self.adapter, self._opt,
                                               self.privacy)
            run_fn = self._run_c
        else:
            run_fn = self._get_obs(
                "_run_obs_c", tel,
                lambda: ENG.make_seq_run(self.adapter, self._opt,
                                         self.privacy, tel))
        key_idx = np.zeros((n_epochs, packed.nb_max), np.uint32)
        if self._keyed:
            for e in range(n_epochs):
                key_idx[e, :nb] = self._take_key_indices(nb)
        batches = {k: v[:, 0] for k, v in batches.items()}    # [E, NB, ...]
        ex_w = None if packed.ex_weights is None else packed.ex_weights[0]
        args = (state["params"], state["opt"], batches, packed.mask[0],
                ex_w, key_idx, self._privacy_base_key())
        with self._span("dispatch"):
            out = run_fn(*args)
        self._count_dispatch()
        self._last_run_invocation = (run_fn, ENG.abstract_args(args))
        state["params"], state["opt"], losses = out[0], out[1], out[2]
        self._run_calls = getattr(self, "_run_calls", 0) + 1
        losses = np.asarray(losses)
        logs = [EpochLog([float(x) for x in losses[e, :nb]], nb,
                         weights=packed.step_examples[0])
                for e in range(n_epochs)]
        if tel is not None:
            met = {k: np.asarray(v) for k, v in out[3].items()}
            for e, log in enumerate(logs):
                log.telemetry = self._round_telemetry(
                    tel, [float(x) for x in losses[e, :nb]],
                    {k: v[e, :nb] for k, v in met.items()})
        for ci in range(self.n_clients):
            self._dp_account(ci, packed.n_samples[0], batch_size,
                             count=nb * n_epochs)
        return state, logs

    def params_for_eval(self, state, client_idx):
        return state["params"]
