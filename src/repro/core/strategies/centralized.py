"""Centralized training — the paper's benchmark upper bound (§3.6)."""

from __future__ import annotations

import numpy as np

from repro.core.strategies.base import (Strategy, EpochLog, make_full_step,
                                        np_batches)


class Centralized(Strategy):
    name = "centralized"

    def setup(self, key):
        params = self.adapter.init(key)
        if not hasattr(self, "_opt"):
            self._opt = self.opt_factory()
            self._step = make_full_step(self.adapter, self._opt,
                                        self.privacy)
        return {"params": params, "opt": self._opt.init(params)}

    def run_epoch(self, state, client_data, rng, batch_size):
        pooled = {k: np.concatenate([d[k] for d in client_data])
                  for k in client_data[0]}
        n_pooled = len(pooled["label"])
        losses = []
        for batch in np_batches(pooled, batch_size, rng):
            if self._keyed:
                state["params"], state["opt"], loss = self._step(
                    state["params"], state["opt"], batch, self._next_key())
            else:
                state["params"], state["opt"], loss = self._step(
                    state["params"], state["opt"], batch)
            losses.append(float(loss))
            # centralized DP: every hospital's records sit in the pooled
            # set, so each carries the same pooled-rate guarantee
            for ci in range(self.n_clients):
                self._dp_account(ci, n_pooled, batch_size)
        return state, EpochLog(losses, len(losses))

    def params_for_eval(self, state, client_idx):
        return state["params"]
