"""Centralized training — the paper's benchmark upper bound (§3.6)."""

from __future__ import annotations

import numpy as np

from repro.core.strategies.base import (Strategy, EpochLog, make_full_step,
                                        np_batches)


class Centralized(Strategy):
    name = "centralized"

    def setup(self, key):
        params = self.adapter.init(key)
        if not hasattr(self, "_opt"):
            self._opt = self.opt_factory()
            self._step = make_full_step(self.adapter, self._opt)
        return {"params": params, "opt": self._opt.init(params)}

    def run_epoch(self, state, client_data, rng, batch_size):
        pooled = {k: np.concatenate([d[k] for d in client_data])
                  for k in client_data[0]}
        losses = []
        for batch in np_batches(pooled, batch_size, rng):
            state["params"], state["opt"], loss = self._step(
                state["params"], state["opt"], batch)
            losses.append(float(loss))
        return state, EpochLog(losses, len(losses))

    def params_for_eval(self, state, client_idx):
        return state["params"]
