"""Centralized training — the paper's benchmark upper bound (§3.6)."""

from __future__ import annotations

import numpy as np

from repro.core.strategies.base import (Strategy, EpochLog, make_full_step,
                                        np_batches)


class Centralized(Strategy):
    name = "centralized"
    shared_eval_params = True

    def setup(self, key):
        params = self.adapter.init(key)
        if not hasattr(self, "_opt"):
            self._opt = self.opt_factory()
            self._step = make_full_step(self.adapter, self._opt,
                                        self.privacy)
        return {"params": params, "opt": self._opt.init(params)}

    def run_epoch(self, state, client_data, rng, batch_size):
        pooled = {k: np.concatenate([d[k] for d in client_data])
                  for k in client_data[0]}
        if self.engine == "compiled":
            return self._run_epoch_compiled(state, pooled, rng, batch_size)
        n_pooled = len(pooled["label"])
        losses, weights = [], []
        for batch in np_batches(pooled, batch_size, rng,
                                self.drop_remainder):
            if self._keyed:
                state["params"], state["opt"], loss = self._step(
                    state["params"], state["opt"], batch, self._next_key())
            else:
                state["params"], state["opt"], loss = self._step(
                    state["params"], state["opt"], batch)
            losses.append(float(loss))
            weights.append(len(batch["label"]))
            # centralized DP: every hospital's records sit in the pooled
            # set, so each carries the same pooled-rate guarantee
            for ci in range(self.n_clients):
                self._dp_account(ci, n_pooled, batch_size)
        return state, EpochLog(losses, len(losses), weights=weights)

    def _run_epoch_compiled(self, state, pooled, rng, batch_size):
        from repro.core.strategies import engine as ENG
        packed = ENG.pack_epoch([pooled], batch_size, rng,
                                self.drop_remainder)
        nb = packed.n_batches[0]
        if nb == 0:
            return state, EpochLog([], 0)
        if not hasattr(self, "_epoch_c"):
            self._epoch_c = ENG.make_seq_epoch(self.adapter, self._opt,
                                               self.privacy)
        key_idx = np.zeros((packed.nb_max,), np.uint32)
        if self._keyed:
            key_idx[:nb] = self._take_key_indices(nb)
        batches = {k: v[0] for k, v in packed.batches.items()}
        ex_w = None if packed.ex_weights is None else packed.ex_weights[0]
        state["params"], state["opt"], losses = self._epoch_c(
            state["params"], state["opt"], batches, packed.mask[0], ex_w,
            key_idx, self._privacy_base_key())
        flat = [float(x) for x in np.asarray(losses)[:nb]]
        for ci in range(self.n_clients):
            self._dp_account(ci, packed.n_samples[0], batch_size, count=nb)
        return state, EpochLog(flat, nb, weights=packed.step_examples[0])

    @property
    def _whole_run(self):
        return True

    def _run_compiled(self, state, client_data, rng, batch_size, n_epochs):
        from repro.core.strategies import engine as ENG
        pooled = {k: np.concatenate([d[k] for d in client_data])
                  for k in client_data[0]}
        if ENG.empty_run([pooled], batch_size, self.drop_remainder):
            return None
        batches, packed = ENG.pack_run([pooled], batch_size, rng, n_epochs,
                                       self.drop_remainder)
        nb = packed.n_batches[0]
        if not hasattr(self, "_run_c"):
            self._run_c = ENG.make_seq_run(self.adapter, self._opt,
                                           self.privacy)
        key_idx = np.zeros((n_epochs, packed.nb_max), np.uint32)
        if self._keyed:
            for e in range(n_epochs):
                key_idx[e, :nb] = self._take_key_indices(nb)
        batches = {k: v[:, 0] for k, v in batches.items()}    # [E, NB, ...]
        ex_w = None if packed.ex_weights is None else packed.ex_weights[0]
        state["params"], state["opt"], losses = self._run_c(
            state["params"], state["opt"], batches, packed.mask[0], ex_w,
            key_idx, self._privacy_base_key())
        self._run_calls = getattr(self, "_run_calls", 0) + 1
        losses = np.asarray(losses)
        logs = [EpochLog([float(x) for x in losses[e, :nb]], nb,
                         weights=packed.step_examples[0])
                for e in range(n_epochs)]
        for ci in range(self.n_clients):
            self._dp_account(ci, packed.n_samples[0], batch_size,
                             count=nb * n_epochs)
        return state, logs

    def params_for_eval(self, state, client_idx):
        return state["params"]
