"""Compiled multi-hospital execution engine.

The stepwise engine (the legacy path in each strategy, kept as the parity
reference) dispatches one jitted step per mini-batch per hospital from a
Python host loop — wall-clock is dominated by dispatch overhead and
hospitals run strictly sequentially.  This module lowers a WHOLE epoch —
and, via the ``make_*_run`` builders, a whole multi-epoch training RUN —
into a single XLA program instead:

  * **pad-and-mask layout** — each hospital's shuffled epoch is packed into
    rectangular ``[n_clients, n_batches, batch, ...]`` arrays plus a
    ``[n_clients, n_batches]`` validity mask; uneven hospital sizes become
    masked (no-op) scan steps and, with ``drop_remainder=False``, the final
    short batch becomes per-example weights instead of a ragged shape.
  * **scan over batches, vmap over hospitals** where semantics allow it:
    FL local epochs are independent per hospital, so the per-client
    ``lax.scan`` is wrapped in a ``vmap`` over the stacked hospital axis.
  * **scanned interleave** where they don't: the SL/SFLv2 server segment
    (and its Adam state) is shared and updated sequentially in schedule
    order, so the epoch is ONE ``lax.scan`` over the dense
    ``[step] -> (client, batch)`` schedule array from
    ``repro.core.schedule.schedule_array`` — exact sequential Adam
    semantics, zero host dispatches.
  * **per-step PRNG keys by fold-in on the scan index**: the stepwise path
    draws key ``fold_in(base, t)`` for the t-th step of the run; the packer
    reserves the same running counter (``Strategy._take_key_indices``) and
    the scan body folds the reserved index in, so DP-SGD / cut-layer noise
    draws are bit-identical across engines.
  * **scan over rounds** (``make_fl_run`` / ``make_seq_run`` /
    ``make_interleaved_run`` / ``make_sflv3_run``): an outer ``lax.scan``
    over the epoch axis of ``pack_run``'s ``[n_epochs, ...]`` batch stack
    wraps the epoch body, with the FedAvg weighted aggregation (secagg
    off) and the SFLv2/v1 client-segment averaging folded into the round
    body — a whole ``Strategy.run(n_epochs)`` becomes ONE host dispatch,
    and per-round losses come back stacked ``[n_epochs, ...]`` for the
    per-round ``EpochLog``s.  Per-round key-index grids keep consuming the
    same running counter, epoch-major, so keyed draws stay bit-identical
    to a stepwise multi-epoch loop.

Every scan body calls the SAME pure step functions
(``repro.core.strategies.base.{full,split,sflv3}_step_fn``) the stepwise
jit wrappers use, which is what makes the two engines numerically
equivalent (asserted at 1e-5 in tests/test_engine.py).

Device placement of the hospital axis lives in ``repro.core.placement``:
a ``Placement`` pads ``n_clients`` up to a device multiple with phantom
hospitals (``pack_epoch(pad_clients=...)``) and ``device_put``s every
``[C, ...]`` stack across a 1-D ``("hosp",)`` mesh; on a single device it
is a no-op, so the engine runs unchanged on one CPU and scales to a
multi-device host.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregate import (mean_sync as _mean_sync,
                                  stacked_mean_sync, stacked_weighted_mean,
                                  weighted_mean_normalized as _weighted_mean)
from repro.core.partition import (SplitAdapter, tree_put, tree_select,
                                  tree_take)
from repro.core.strategies.base import (full_step_fn, sflv3_step_fn,
                                        split_step_fn)
from repro import optim as O


# ---------------------------------------------------------------------------
# pad-and-mask epoch packing
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PackedEpoch:
    """One epoch of every hospital's data in rectangular form.

    ``batches[k]`` has shape ``[n_clients, nb_max, batch, ...]``; rows past
    a hospital's real data are zero padding flagged invalid by ``mask``.
    ``ex_weights`` (only with ``drop_remainder=False``) carries per-example
    validity for the final short batch of each hospital.
    """
    batches: dict
    mask: np.ndarray                       # [C, NB] bool
    ex_weights: np.ndarray | None          # [C, NB, B] float32
    n_batches: list
    step_examples: list                    # per client: valid-example counts
    n_samples: list
    batch_size: int

    @property
    def nb_max(self) -> int:
        return self.mask.shape[1]

    @property
    def total_steps(self) -> int:
        return int(sum(self.n_batches))


def _client_batch_count(n: int, batch_size: int,
                        drop_remainder: bool) -> tuple[int, int, int]:
    """``(nb, nb_full, rem)`` for one hospital of ``n`` samples — THE
    batching rule (mirroring ``np_batches``), shared by ``pack_epoch``
    and ``empty_run`` so the two can never drift."""
    nb_full, rem = divmod(n, batch_size)
    return nb_full + (1 if rem and not drop_remainder else 0), nb_full, rem


def pack_epoch(client_data: list, batch_size: int,
               rng: np.random.Generator | None,
               drop_remainder: bool = True,
               pad_clients: int = 0) -> PackedEpoch:
    """Shuffle + pack every hospital's epoch (mirrors ``np_batches``).

    The per-client shuffles consume ``rng`` in hospital order — exactly the
    draws the stepwise path makes — so both engines train on identical
    batch compositions.

    ``pad_clients`` appends that many *phantom hospitals* (zero samples,
    zero batches, all-False mask rows) so the hospital axis reaches a
    device multiple for ``core.placement`` — phantom rows are masked
    no-ops in every scan and carry zero weight in every aggregation.
    """
    n_batches, n_samples, step_examples, order = [], [], [], []
    for d in client_data:
        n = len(next(iter(d.values())))
        idx = np.arange(n)
        if rng is not None:
            rng.shuffle(idx)
        nb, nb_full, rem = _client_batch_count(n, batch_size,
                                               drop_remainder)
        order.append(idx)
        n_batches.append(nb)
        n_samples.append(n)
        step_examples.append([batch_size] * nb_full
                             + ([rem] if nb > nb_full else []))
    NB = max(n_batches, default=0)
    n_batches += [0] * pad_clients
    n_samples += [0] * pad_clients
    step_examples += [[] for _ in range(pad_clients)]
    C = len(client_data) + pad_clients

    batches = {}
    for k in client_data[0]:
        proto = client_data[0][k]
        out = np.zeros((C, NB * batch_size, *proto.shape[1:]), proto.dtype)
        for c, d in enumerate(client_data):
            used = (n_batches[c] * batch_size if drop_remainder
                    else n_samples[c])
            out[c, :used] = d[k][order[c][:used]]
        batches[k] = out.reshape(C, NB, batch_size, *proto.shape[1:])

    mask = np.zeros((C, NB), bool)
    ex_w = (None if drop_remainder
            else np.zeros((C, NB, batch_size), np.float32))
    for c in range(C):
        mask[c, :n_batches[c]] = True
        if ex_w is not None:
            for j, m in enumerate(step_examples[c]):
                ex_w[c, j, :m] = 1.0
    return PackedEpoch(batches, mask, ex_w, n_batches, step_examples,
                       n_samples, batch_size)


# ---------------------------------------------------------------------------
# compiled epoch kernels
# ---------------------------------------------------------------------------

def _step_key(base_key, idx, keyed):
    if not keyed:
        return None
    from repro.privacy.dpsgd import step_key
    return step_key(base_key, idx)


def _fl_epoch_body(adapter: SplitAdapter, opt: O.Optimizer, privacy=None,
                   placement=None, telemetry=None):
    """Traceable FL round: vmap-over-hospitals of scan-over-batches.
    Shared verbatim by ``make_fl_epoch`` and ``make_fl_run``'s round scan
    — one definition is what keeps the two numerically identical.

    With an enabled ``placement`` the hospital axis runs under
    ``shard_map`` on the "hosp" mesh: each device vmaps over its own
    hospital chunk with the global params replicated.  (The XLA SPMD
    partitioner cannot split the grouped-conv lowering of a vmapped CNN
    along the mapped axis, so per-device chunking is done explicitly —
    local epochs are independent, so no collectives are needed.)

    With a ``telemetry`` spec the observed step's metric dict rides the
    scan as an extra output — the epoch returns one extra trailing
    ``met`` dict of ``[C, NB]`` arrays (sharded like the losses under
    placement).  Params are bit-identical: the update math is untouched,
    only additional outputs are stacked.
    """
    step, keyed = full_step_fn(adapter, opt, privacy, telemetry)
    observed = telemetry is not None

    def all_clients(gp, bk, batches, mask, ex_w, key_idx):
        def per_client(b_c, m_c, w_c, ki_c):
            def body(carry, xs):
                p, s = carry
                batch, m, w, ki = xs
                out = step(p, s, batch, _step_key(bk, ki, keyed), w)
                p2, s2, loss = out[0], out[1], out[2]
                ys = (loss, out[3]) if observed else loss
                return (tree_select(m, p2, p), tree_select(m, s2, s)), ys

            (p, _), ys = jax.lax.scan(
                body, (gp, opt.init(gp)), (b_c, m_c, w_c, ki_c))
            return (p, *ys) if observed else (p, ys)

        return jax.vmap(per_client)(batches, mask, ex_w, key_idx)

    def epoch(global_params, batches, mask, ex_w, key_idx, base_key):
        if placement is None or not placement.enabled:
            return all_clients(global_params, base_key, batches, mask,
                               ex_w, key_idx)
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        H = P("hosp")
        sm = shard_map(all_clients, mesh=placement.mesh,
                       in_specs=(P(), P(), H, H, H, H),
                       out_specs=(H, H, H) if observed else (H, H),
                       check_rep=False)
        return sm(global_params, base_key, batches, mask, ex_w, key_idx)

    return epoch


def make_fl_epoch(adapter: SplitAdapter, opt: O.Optimizer, privacy=None,
                  placement=None, telemetry=None):
    """FL round as vmap-over-hospitals of scan-over-batches.

    Every hospital starts from the broadcast global params with a fresh
    optimizer (FedAvg semantics); masked steps are no-ops via
    ``tree_select`` so the Adam step counter never advances on padding.
    Returns ``epoch(global_params, batches, mask, ex_w, key_idx, base_key)
    -> (stacked local params, [C, NB] losses)`` — plus a trailing ``met``
    dict of ``[C, NB]`` metric taps with a ``telemetry`` spec.
    """
    return jax.jit(_fl_epoch_body(adapter, opt, privacy, placement,
                                  telemetry))


def _seq_epoch_body(adapter: SplitAdapter, opt: O.Optimizer, privacy=None,
                    telemetry=None):
    """Traceable centralized epoch: one scan-over-batches with persistent
    optimizer state; shared by ``make_seq_epoch`` and ``make_seq_run``."""
    step, keyed = full_step_fn(adapter, opt, privacy, telemetry)
    observed = telemetry is not None

    def epoch(params, opt_state, batches, mask, ex_w, key_idx, base_key):
        def body(carry, xs):
            p, s = carry
            batch, m, w, ki = xs
            out = step(p, s, batch, _step_key(base_key, ki, keyed), w)
            p2, s2, loss = out[0], out[1], out[2]
            ys = (loss, out[3]) if observed else loss
            return (tree_select(m, p2, p), tree_select(m, s2, s)), ys

        (params, opt_state), ys = jax.lax.scan(
            body, (params, opt_state), (batches, mask, ex_w, key_idx))
        if observed:
            return (params, opt_state, *ys)
        return params, opt_state, ys

    return epoch


def make_seq_epoch(adapter: SplitAdapter, opt: O.Optimizer, privacy=None,
                   telemetry=None):
    """Centralized epoch as a single scan-over-batches (one 'hospital',
    persistent optimizer state).  Returns ``epoch(params, opt_state,
    batches, mask, ex_w, key_idx, base_key) -> (params, opt_state,
    [NB] losses)`` — plus a trailing ``met`` dict of ``[NB]`` taps with a
    ``telemetry`` spec."""
    return jax.jit(_seq_epoch_body(adapter, opt, privacy, telemetry))


def _interleaved_epoch_body(adapter: SplitAdapter, opt_client: O.Optimizer,
                            opt_server: O.Optimizer, transport=None,
                            privacy=None, telemetry=None):
    """Traceable SL/SFLv2 epoch: ONE scan over the dense schedule array;
    shared by ``make_interleaved_epoch`` and ``make_interleaved_run``."""
    step, keyed = split_step_fn(adapter, opt_client, opt_server, transport,
                                privacy, telemetry)
    observed = telemetry is not None

    def epoch(stacked_clients, server, stacked_c_opts, s_opt, batches,
              ex_w, sched, key_idx, base_key):
        def body(carry, xs):
            sc, sp, co, so = carry
            cb, ki = xs
            c, b = cb[0], cb[1]
            batch = jax.tree.map(lambda x: x[c, b], batches)
            w = None if ex_w is None else ex_w[c, b]
            out = step(
                tree_take(sc, c), sp, tree_take(co, c), so, batch,
                _step_key(base_key, ki, keyed), w)
            cp, sp, cop, so, loss = out[0], out[1], out[2], out[3], out[4]
            ys = (loss, out[5]) if observed else loss
            return (tree_put(sc, c, cp), sp, tree_put(co, c, cop), so), ys

        carry, ys = jax.lax.scan(
            body, (stacked_clients, server, stacked_c_opts, s_opt),
            (sched, key_idx))
        return (*carry, *ys) if observed else (*carry, ys)

    return epoch


def make_interleaved_epoch(adapter: SplitAdapter, opt_client: O.Optimizer,
                           opt_server: O.Optimizer, transport=None,
                           privacy=None, telemetry=None):
    """SL/SFLv2 epoch as ONE scan over the dense schedule array.

    The shared server segment forces sequential semantics: each scan step
    gathers client ``c``'s segment + optimizer slice from the stacked
    hospital axis, runs the exact split step, and scatters the update back.
    Returns ``epoch(stacked_clients, server, stacked_c_opts, s_opt,
    batches, ex_w, sched, key_idx, base_key) -> (stacked_clients, server,
    stacked_c_opts, s_opt, [steps] losses)`` — plus a trailing ``met``
    dict of ``[steps]`` metric taps with a ``telemetry`` spec.
    """
    return jax.jit(_interleaved_epoch_body(adapter, opt_client, opt_server,
                                           transport, privacy, telemetry))


def _sflv3_epoch_body(adapter: SplitAdapter, opt_client: O.Optimizer,
                      opt_server: O.Optimizer, n_clients: int,
                      transport=None, privacy=None, client_weights=None,
                      placement=None, telemetry=None):
    """Traceable SplitFedv3/v1 epoch: scan over synchronous steps with the
    vmapped per-client step inside; shared by ``make_sflv3_epoch`` and
    ``make_sflv3_run``.  ``n_clients`` is the ARRAY hospital count (a
    placement's padded ``c_pad``); ``client_weights`` zeroes phantom rows
    out of the server-gradient average.

    With an enabled ``placement`` the scan runs under ``shard_map``: each
    device scans over its own hospital chunk (vmapped conv programs never
    meet the SPMD partitioner) and the per-step server-gradient average is
    completed with one ``psum`` — the server (and its Adam state) stays
    replicated, client segments and their Adam state stay sharded.
    """
    sharded = placement is not None and placement.enabled
    observed = telemetry is not None
    if sharded:
        local = placement.c_pad // placement.mesh.devices.size
        weights = (placement.client_weights() if client_weights is None
                   else client_weights)
        step, keyed = sflv3_step_fn(adapter, opt_client, opt_server, local,
                                    transport, privacy, weights,
                                    mesh_axis="hosp", telemetry=telemetry)
    else:
        local = n_clients
        step, keyed = sflv3_step_fn(adapter, opt_client, opt_server,
                                    n_clients, transport, privacy,
                                    client_weights, telemetry=telemetry)

    def chunk_epoch(stacked_clients, server, c_opt, s_opt, batches, b_idx,
                    key_idx, base_key):
        def body(carry, xs):
            sc, sp, co, so = carry
            bi, ki = xs
            batch = jax.tree.map(
                lambda x: x[jnp.arange(local), bi], batches)
            out = step(sc, sp, co, so, batch,
                       _step_key(base_key, ki, keyed))
            ys = (out[4], out[5]) if observed else out[4]
            return out[:4], ys

        carry, ys = jax.lax.scan(
            body, (stacked_clients, server, c_opt, s_opt), (b_idx, key_idx))
        return (*carry, *ys) if observed else (*carry, ys)

    if not sharded:
        return chunk_epoch

    def epoch(stacked_clients, server, c_opt, s_opt, batches, b_idx,
              key_idx, base_key):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        H = P("hosp")
        SC = P(None, "hosp")       # [steps, C] losses / met taps
        sm = shard_map(
            chunk_epoch, mesh=placement.mesh,
            # c_opt mixes [C, ...] leaves with the scalar Adam count, so it
            # needs per-leaf specs; server + its opt state are replicated
            in_specs=(H, P(), placement.leaf_specs(c_opt), P(), H,
                      P(None, "hosp"), P(), P()),
            out_specs=(H, P(), placement.leaf_specs(c_opt), P(), SC, SC)
            if observed else
            (H, P(), placement.leaf_specs(c_opt), P(), SC),
            check_rep=False)
        return sm(stacked_clients, server, c_opt, s_opt, batches, b_idx,
                  key_idx, base_key)

    return epoch


def make_sflv3_epoch(adapter: SplitAdapter, opt_client: O.Optimizer,
                     opt_server: O.Optimizer, n_clients: int, transport=None,
                     privacy=None, client_weights=None, placement=None,
                     telemetry=None):
    """SplitFedv3 epoch: scan over synchronous steps, vmap over hospitals
    inside each step (the step fn already vmaps), with the wrap-around
    batch index precomputed as a dense ``[steps, n_clients]`` array.
    Returns ``epoch(stacked_clients, server, c_opt, s_opt, batches, b_idx,
    key_idx, base_key) -> (..., [steps, C] losses)`` — plus a trailing
    ``met`` dict of ``[steps, C]`` metric taps with a ``telemetry``
    spec."""
    return jax.jit(_sflv3_epoch_body(adapter, opt_client, opt_server,
                                     n_clients, transport, privacy,
                                     client_weights, placement, telemetry))


def _update_cosine(stacked, gp, new_gp, eps=1e-12):
    """Per-hospital cosine between each local FedAvg update delta
    (``local_c - global``) and the aggregated mean delta
    (``new_global - global``) — the round's update-agreement tap
    (traceable; shared by ``make_fl_run``'s round body and the jitted
    host-callable ``update_cosine`` the per-epoch paths use).  Zero
    deltas (phantom rows, no-op rounds) report cosine 0."""
    C = jax.tree.leaves(stacked)[0].shape[0]
    deltas = jnp.concatenate(
        [l.reshape(C, -1).astype(jnp.float32) - g.reshape(-1).astype(
            jnp.float32)[None]
         for l, g in zip(jax.tree.leaves(stacked), jax.tree.leaves(gp))],
        axis=1)
    mean_d = jnp.concatenate(
        [(n.astype(jnp.float32) - g.astype(jnp.float32)).reshape(-1)
         for n, g in zip(jax.tree.leaves(new_gp), jax.tree.leaves(gp))])
    num = deltas @ mean_d
    den = (jnp.linalg.norm(deltas, axis=1) * jnp.linalg.norm(mean_d))
    return num / (den + eps)


@jax.jit
def update_cosine(stacked, gp, new_gp):
    """Host-callable ``_update_cosine`` for the per-epoch / stepwise FL
    paths (the whole-run engine computes it inside the round scan)."""
    return _update_cosine(stacked, gp, new_gp)


# ``stacked_weighted_mean`` / ``stacked_mean_sync`` (and the traceable
# ``_weighted_mean`` / ``_mean_sync`` cores the run builders inline) now
# live in ``repro.core.aggregate`` — imported above, re-exported here for
# the strategies' compiled paths and external callers.


# ---------------------------------------------------------------------------
# whole-run kernels — scan over rounds around the epoch bodies above
# ---------------------------------------------------------------------------

def _donating_jit(fn, donate_argnums):
    """jit the whole-run body with its big buffers donated.

    The run carries (params / optimizer state, which the scan returns with
    identical shapes — XLA aliases them in place) and the packed
    ``[E, C, NB, B, ...]`` batch stack (no aliasable output, but freeing it
    at entry lets the allocator reuse the run's largest buffer as scratch)
    are dead to the caller the moment the run is dispatched: every strategy
    immediately overwrites its state with the outputs.  Donating them cuts
    peak HBM by roughly the input footprint.  XLA warns per donated buffer
    it could not alias (the batch stack, by design) — that warning is
    filtered here, scoped to the call.

    ``.lower`` is re-exposed for ``obs.profile.hlo_cost``, which re-lowers
    the stored invocation from abstract avals (``abstract_args``).
    """
    jfn = jax.jit(fn, donate_argnums=donate_argnums)

    @functools.wraps(fn)
    def wrapper(*args):
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            return jfn(*args)

    wrapper.lower = jfn.lower
    return wrapper


def abstract_args(args):
    """Concrete invocation args -> ``ShapeDtypeStruct`` skeleton.

    What the strategies stash as ``_last_run_invocation``: ``jit.lower``
    accepts the abstract avals, so ``hlo_cost`` can re-lower the exact
    program without the stash pinning the run's donated (deleted) buffers
    or the multi-epoch batch stack in memory.
    """
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), jnp.asarray(a).dtype)
        if not isinstance(a, jax.ShapeDtypeStruct) else a, args)

def empty_run(client_data, batch_size: int,
              drop_remainder: bool = True) -> bool:
    """True when no hospital yields a single batch.  Checked BEFORE
    ``pack_run`` so a degenerate ``Strategy.run`` can fall back to the
    per-epoch path without having consumed any shuffle draws from the
    host rng."""
    for d in client_data:
        n = len(next(iter(d.values())))
        if _client_batch_count(n, batch_size, drop_remainder)[0]:
            return False
    return True


def pack_run(client_data, batch_size: int, rng, n_epochs: int,
             drop_remainder: bool = True, pad_clients: int = 0):
    """Pack ``n_epochs`` epochs into ``[n_epochs, n_clients, nb_max, ...]``.

    Consumes ``rng`` exactly as a stepwise loop of per-epoch packs would
    (epoch-major, hospital order inside each epoch), so both engines train
    on identical batch compositions.  Batch counts, masks and per-example
    weights are epoch-invariant (data sizes never change mid-run) — only
    the shuffles differ — so the returned ``PackedEpoch`` meta is the
    first epoch's.  ``pad_clients`` phantom hospitals (see ``pack_epoch``)
    ride along on axis 1.  Memory grows linearly with ``n_epochs`` (the
    whole run's batch grid lives in one buffer); callers with huge runs
    can chunk ``run`` into several calls.
    """
    packs = [pack_epoch(client_data, batch_size, rng, drop_remainder,
                        pad_clients)
             for _ in range(n_epochs)]
    batches = {k: np.stack([p.batches[k] for p in packs])
               for k in packs[0].batches}
    return batches, packs[0]


def make_fl_run(adapter: SplitAdapter, opt: O.Optimizer, privacy=None,
                placement=None, telemetry=None, aggregator=None):
    """Whole FL training run as ONE program: ``lax.scan`` over rounds, each
    round the SAME vmap-over-hospitals scan-over-batches body
    ``make_fl_epoch`` jits, followed by the in-graph data-size-weighted
    FedAvg aggregation.  (Secure aggregation needs host-side per-client
    masked uploads and keeps the per-round path.)  Under placement the
    epoch body runs in ``shard_map`` chunks and the FedAvg reduction over
    the sharded hospital axis lowers to one all-reduce per round.
    Phantom rows carry zero aggregation weight.  Returns
    ``run(global_params, batches[E,C,NB,...], mask, ex_w, key_idx[E,C,NB],
    base_key, agg_weights[C]) -> (params, [E,C,NB] losses)``.

    With a ``telemetry`` spec the round body also stacks the step metric
    taps (``met`` dict of ``[E, C, NB]`` arrays) and — when the spec asks
    for ``update_cosine`` — each round's per-hospital cosine between the
    local delta and the aggregated mean delta (``[E, C]``), computed
    in-graph from the stacked locals the round already holds: the run
    stays ONE dispatch and the FedAvg math is untouched.

    ``aggregator=None`` keeps the inlined pre-normalized weighted mean
    (bit-identical to pre-PR-9 programs); a scan-compatible
    ``core.aggregate.Aggregator`` replaces the round reduction in-graph.
    """
    epoch = _fl_epoch_body(adapter, opt, privacy, placement, telemetry)
    observed = telemetry is not None
    want_cos = observed and telemetry.update_cosine

    def run(global_params, batches, mask, ex_w, key_idx, base_key, agg_w):
        if aggregator is None:
            w = agg_w.astype(jnp.float32) / agg_w.astype(jnp.float32).sum()
            reduce = lambda stacked, gp: _weighted_mean(stacked, w)
        else:
            reduce = lambda stacked, gp: aggregator.aggregate(
                stacked, agg_w, gp)

        def round_body(gp, xs):
            b_e, ki_e = xs
            if observed:
                stacked, losses, met = epoch(gp, b_e, mask, ex_w, ki_e,
                                             base_key)
                new_gp = reduce(stacked, gp)
                if want_cos:
                    met = dict(met)
                    met["update_cosine"] = _update_cosine(stacked, gp,
                                                          new_gp)
                return new_gp, (losses, met)
            stacked, losses = epoch(gp, b_e, mask, ex_w, ki_e, base_key)
            return reduce(stacked, gp), losses

        return jax.lax.scan(round_body, global_params, (batches, key_idx))

    # donate the param carry (aliased into the output) + the batch stack
    return _donating_jit(run, donate_argnums=(0, 1))


def make_seq_run(adapter: SplitAdapter, opt: O.Optimizer, privacy=None,
                 telemetry=None):
    """Whole centralized run: scan over epochs around ``make_seq_epoch``'s
    scan-over-batches body (persistent optimizer state across epochs).
    Returns ``run(params, opt_state, batches[E,NB,...], mask[NB], ex_w,
    key_idx[E,NB], base_key) -> (params, opt_state, [E,NB] losses)`` —
    plus a trailing ``met`` dict of ``[E, NB]`` taps with a ``telemetry``
    spec."""
    epoch = _seq_epoch_body(adapter, opt, privacy, telemetry)
    observed = telemetry is not None

    def run(params, opt_state, batches, mask, ex_w, key_idx, base_key):
        def round_body(carry, xs):
            b_e, ki_e = xs
            out = epoch(*carry, b_e, mask, ex_w, ki_e, base_key)
            ys = (out[2], out[3]) if observed else out[2]
            return (out[0], out[1]), ys

        (params, opt_state), ys = jax.lax.scan(
            round_body, (params, opt_state), (batches, key_idx))
        if observed:
            return (params, opt_state, *ys)
        return params, opt_state, ys

    return _donating_jit(run, donate_argnums=(0, 1, 2))


def make_interleaved_run(adapter: SplitAdapter, opt_client: O.Optimizer,
                         opt_server: O.Optimizer, transport=None,
                         privacy=None, sync_clients: bool = False,
                         client_weights=None, telemetry=None):
    """Whole SL/SFLv2 run: scan over epochs around the scanned schedule
    interleave body ``make_interleaved_epoch`` jits.  ``sync_clients``
    folds the SFLv2 end-of-epoch client fed-averaging into the round
    body (``client_weights`` excludes placement phantom rows from it).
    The schedule array is epoch-invariant (batch counts never
    change) and is rescanned each round; per-epoch key indices arrive as
    ``key_idx[E, steps]``.  Returns ``run(stacked_clients, server,
    stacked_c_opts, s_opt, batches[E,C,NB,...], ex_w, sched, key_idx,
    base_key) -> (..., [E, steps] losses)``.
    """
    epoch = _interleaved_epoch_body(adapter, opt_client, opt_server,
                                    transport, privacy, telemetry)
    observed = telemetry is not None
    sync_w = (None if client_weights is None
              else jnp.asarray(client_weights, jnp.float32))

    def run(stacked_clients, server, stacked_c_opts, s_opt, batches, ex_w,
            sched, key_idx, base_key):
        def round_body(carry, xs):
            b_e, ki_e = xs
            out = epoch(*carry, b_e, ex_w, sched, ki_e, base_key)
            sc, sp, co, so = out[0], out[1], out[2], out[3]
            ys = (out[4], out[5]) if observed else out[4]
            if sync_clients:
                sc = _mean_sync(sc, sync_w)
            return (sc, sp, co, so), ys

        carry, ys = jax.lax.scan(
            round_body, (stacked_clients, server, stacked_c_opts, s_opt),
            (batches, key_idx))
        return (*carry, *ys) if observed else (*carry, ys)

    return _donating_jit(run, donate_argnums=(0, 1, 2, 3, 4))


def make_sflv3_run(adapter: SplitAdapter, opt_client: O.Optimizer,
                   opt_server: O.Optimizer, n_clients: int, transport=None,
                   privacy=None, sync_clients: bool = False,
                   client_weights=None, placement=None, telemetry=None):
    """Whole SplitFedv3/v1 run: scan over epochs around the synchronous-
    step scan body ``make_sflv3_epoch`` jits (wrap-around index grid
    ``b_idx`` is epoch-invariant); ``sync_clients`` folds SFLv1's client
    fed-averaging into the round body; ``client_weights`` excludes
    placement phantom rows from server-gradient averaging and syncs.
    Returns ``run(stacked_clients,
    server, c_opt, s_opt, batches[E,C,NB,...], b_idx, key_idx[E,steps],
    base_key) -> (..., [E, steps, C] losses)``."""
    epoch = _sflv3_epoch_body(adapter, opt_client, opt_server, n_clients,
                              transport, privacy, client_weights,
                              placement, telemetry)
    observed = telemetry is not None
    sync_w = (None if client_weights is None
              else jnp.asarray(client_weights, jnp.float32))

    def run(stacked_clients, server, c_opt, s_opt, batches, b_idx, key_idx,
            base_key):
        def round_body(carry, xs):
            b_e, ki_e = xs
            out = epoch(*carry, b_e, b_idx, ki_e, base_key)
            sc, sp, co, so = out[0], out[1], out[2], out[3]
            ys = (out[4], out[5]) if observed else out[4]
            if sync_clients:
                sc = _mean_sync(sc, sync_w)
            return (sc, sp, co, so), ys

        carry, ys = jax.lax.scan(
            round_body, (stacked_clients, server, c_opt, s_opt),
            (batches, key_idx))
        return (*carry, *ys) if observed else (*carry, ys)

    return _donating_jit(run, donate_argnums=(0, 1, 2, 3, 4))


# ---------------------------------------------------------------------------
# participation: per-round K-of-N subsampling into a fixed slot axis
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ParticipationPack:
    """Per-round packing metadata for a participating run.

    The hospital axis is ``n_slots`` wide (K for fixed-size sampling) and
    every per-round array rides the round scan as an INPUT — who
    participates is data, not shape, so the whole run stays one program
    and compute scales with the slot count, not the federation size.
    ``slot_gid[e, s]`` maps slot ``s`` of round ``e`` to its global
    hospital id (-1 for an empty slot: zero weight, all-False mask);
    ``staleness[e, s]`` counts the rounds that hospital sat out since it
    last participated (0 for fresh/first-time rows);
    ``n_batches`` / ``n_samples`` / ``step_examples`` are per GLOBAL
    hospital (epoch-invariant).
    """
    mask: np.ndarray                    # [E, S, NB] bool
    ex_weights: np.ndarray | None       # [E, S, NB, B] float32
    agg_w: np.ndarray                   # [E, S] float32 (data sizes)
    slot_gid: np.ndarray                # [E, S] int32, -1 = empty slot
    part_mask: np.ndarray               # [E, N] bool
    staleness: np.ndarray               # [E, S] float32
    n_batches: list                     # per global hospital
    n_samples: list                     # per global hospital
    step_examples: list                 # per global hospital
    batch_size: int

    @property
    def nb_max(self) -> int:
        return self.mask.shape[2]

    @property
    def n_slots(self) -> int:
        return self.mask.shape[1]

    @property
    def n_rounds(self) -> int:
        return self.mask.shape[0]

    @property
    def n_global(self) -> int:
        return self.part_mask.shape[1]


def pack_participation_run(client_data, batch_size: int, rng,
                           n_epochs: int, participation,
                           drop_remainder: bool = True):
    """Pack ``n_epochs`` participating rounds into
    ``[n_epochs, n_slots, nb_max, batch, ...]`` batch stacks.

    Every round consumes the shared data-shuffle ``rng`` for ALL N
    hospitals in global order — exactly the draws ``pack_run`` makes —
    and only then fills slots with the round's sampled hospitals.  A
    hospital's batch composition therefore depends only on (round,
    hospital), never on who else was sampled (co-sample independence),
    and ``Participation(k=N)`` packs arrays bit-identical to
    ``pack_run``'s.  ``nb_max`` is the max batch count over ALL N
    hospitals, so the slot grid never reshapes across rounds.
    """
    N = len(client_data)
    if participation.n_global != N:
        raise ValueError(f"participation.n_global={participation.n_global} "
                         f"but {N} hospitals were passed")
    S = participation.n_slots
    ns = [len(next(iter(d.values()))) for d in client_data]
    counts = [_client_batch_count(n, batch_size, drop_remainder)
              for n in ns]
    nbs = [c[0] for c in counts]
    step_examples = [[batch_size] * nb_full + ([rem] if nb > nb_full else [])
                     for nb, nb_full, rem in counts]
    NB = max(nbs, default=0)
    proto = client_data[0]
    batches = {k: np.zeros((n_epochs, S, NB, batch_size, *v.shape[1:]),
                           v.dtype) for k, v in proto.items()}
    mask = np.zeros((n_epochs, S, NB), bool)
    ex_w = (None if drop_remainder
            else np.zeros((n_epochs, S, NB, batch_size), np.float32))
    agg_w = np.zeros((n_epochs, S), np.float32)
    slot_gid = np.full((n_epochs, S), -1, np.int32)
    part_mask = np.zeros((n_epochs, N), bool)
    staleness = np.zeros((n_epochs, S), np.float32)
    last_seen: dict = {}
    for e in range(n_epochs):
        ids = participation.round_ids(e)
        if len(ids) > S:
            raise ValueError(f"round {e} sampled {len(ids)} hospitals but "
                             f"only {S} slots are packed")
        part_mask[e, ids] = True
        orders = []
        for g in range(N):
            idx = np.arange(ns[g])
            if rng is not None:
                rng.shuffle(idx)
            orders.append(idx)
        for s, g in enumerate(ids):
            g = int(g)
            slot_gid[e, s] = g
            agg_w[e, s] = ns[g]
            prev_e = last_seen.get(g)
            staleness[e, s] = 0.0 if prev_e is None else float(e - prev_e - 1)
            mask[e, s, :nbs[g]] = True
            used = nbs[g] * batch_size if drop_remainder else ns[g]
            for k, v in client_data[g].items():
                row = np.zeros((NB * batch_size, *v.shape[1:]), v.dtype)
                row[:used] = v[orders[g][:used]]
                batches[k][e, s] = row.reshape(NB, batch_size,
                                               *v.shape[1:])
            if ex_w is not None:
                for j, m in enumerate(step_examples[g]):
                    ex_w[e, s, j, :m] = 1.0
            last_seen[g] = e
    return batches, ParticipationPack(mask, ex_w, agg_w, slot_gid,
                                      part_mask, staleness, nbs, ns,
                                      step_examples, batch_size)


def make_fl_run_participation(adapter: SplitAdapter, opt: O.Optimizer,
                              privacy=None, telemetry=None,
                              aggregator=None):
    """Whole participating FL run as ONE program.

    Same vmap-over-slots scan-over-batches round body as ``make_fl_run``,
    but every per-round array (batch grid, mask, per-example weights,
    key-index grid, aggregation weights, staleness, slot->gid map) is a
    round-scan INPUT: empty slots are all-False-mask zero-weight phantom
    rows, and the aggregation runs in-graph through ``aggregator`` (whose
    zero-total guard keeps the previous globals on a no-client Poisson
    round).  Returns ``run(global_params, batches[E,S,NB,...], mask,
    ex_w, key_idx[E,S,NB], base_key, agg_w[E,S], staleness[E,S],
    slot_gid[E,S]) -> (params, [E,S,NB] losses)`` (plus a ``met`` dict
    with a ``telemetry`` spec, as in ``make_fl_run``).
    """
    epoch = _fl_epoch_body(adapter, opt, privacy, None, telemetry)
    observed = telemetry is not None
    want_cos = observed and telemetry.update_cosine

    def run(global_params, batches, mask, ex_w, key_idx, base_key, agg_w,
            staleness, slot_gid):
        def round_body(gp, xs):
            b_e, m_e, w_e, ki_e, aw_e, st_e, gid_e = xs
            if observed:
                stacked, losses, met = epoch(gp, b_e, m_e, w_e, ki_e,
                                             base_key)
                new_gp = aggregator.aggregate(stacked, aw_e, gp, st_e,
                                              gid_e)
                if want_cos:
                    met = dict(met)
                    met["update_cosine"] = _update_cosine(stacked, gp,
                                                          new_gp)
                return new_gp, (losses, met)
            stacked, losses = epoch(gp, b_e, m_e, w_e, ki_e, base_key)
            return aggregator.aggregate(stacked, aw_e, gp, st_e,
                                        gid_e), losses

        return jax.lax.scan(
            round_body, global_params,
            (batches, mask, ex_w, key_idx, agg_w, staleness, slot_gid))

    return _donating_jit(run, donate_argnums=(0, 1))


def make_interleaved_run_participation(adapter: SplitAdapter,
                                       opt_client: O.Optimizer,
                                       opt_server: O.Optimizer,
                                       n_global: int, transport=None,
                                       privacy=None,
                                       sync_clients: bool = False):
    """Whole participating SL/SFLv2 run as ONE program.

    All N client segments (and their optimizer slices) persist in the
    ``[N, ...]`` carry; each round's dense schedule covers only the
    sampled slots and rides the scan as ``sched[E, steps, 3]`` rows of
    ``(slot, batch, valid)`` — each step gathers the slot's GLOBAL row
    via ``slot_gid``, runs the exact split step, and scatters back;
    invalid padding rows are ``tree_select`` no-ops.  ``sync_clients``
    (SFLv2) broadcasts the sampled slots' post-round mean to every
    global row — the single-global-client-segment semantics.  Returns
    ``run(stacked_clients[N,...], server, stacked_c_opts, s_opt,
    batches[E,S,NB,...], ex_w, sched, key_idx[E,steps], base_key,
    slot_gid[E,S]) -> (..., [E, steps] losses)``.
    """
    step, keyed = split_step_fn(adapter, opt_client, opt_server, transport,
                                privacy)

    def run(stacked_clients, server, stacked_c_opts, s_opt, batches, ex_w,
            sched, key_idx, base_key, slot_gid):
        def round_body(carry, xs):
            sc0, sp0, co0, so0 = carry
            b_e, w_e, sched_e, ki_e, gid_e = xs

            def body(c2, xs2):
                sc, sp, co, so = c2
                row, ki = xs2
                slot, b, valid = row[0], row[1], row[2]
                g = jnp.maximum(gid_e[slot], 0)
                batch = jax.tree.map(lambda x: x[slot, b], b_e)
                w = None if w_e is None else w_e[slot, b]
                cp, cop = tree_take(sc, g), tree_take(co, g)
                out = step(cp, sp, cop, so, batch,
                           _step_key(base_key, ki, keyed), w)
                v = valid > 0
                cp2 = tree_select(v, out[0], cp)
                sp2 = tree_select(v, out[1], sp)
                cop2 = tree_select(v, out[2], cop)
                so2 = tree_select(v, out[3], so)
                loss = jnp.where(v, out[4], 0.0)
                return (tree_put(sc, g, cp2), sp2,
                        tree_put(co, g, cop2), so2), loss

            (sc, sp, co, so), losses = jax.lax.scan(
                body, (sc0, sp0, co0, so0), (sched_e, ki_e))
            if sync_clients:
                w_slots = (gid_e >= 0).astype(jnp.float32)
                rows = jax.tree.map(lambda x: x[jnp.maximum(gid_e, 0)], sc)
                wn = w_slots / jnp.maximum(w_slots.sum(), 1.0)

                def leaf(x):
                    wx = wn.reshape((-1,) + (1,) * (x.ndim - 1))
                    return (x.astype(jnp.float32) * wx).sum(axis=0)

                m = jax.tree.map(leaf, rows)
                sc = jax.tree.map(
                    lambda x, mm: jnp.broadcast_to(
                        mm.astype(x.dtype)[None], x.shape), sc, m)
            return (sc, sp, co, so), losses

        carry, losses = jax.lax.scan(
            round_body, (stacked_clients, server, stacked_c_opts, s_opt),
            (batches, ex_w, sched, key_idx, slot_gid))
        return (*carry, losses)

    return _donating_jit(run, donate_argnums=(0, 1, 2, 3, 4))


def make_sflv3_run_participation(adapter: SplitAdapter,
                                 opt_client: O.Optimizer,
                                 opt_server: O.Optimizer, k_slots: int,
                                 n_global: int, transport=None,
                                 privacy=None, sync_clients: bool = False):
    """Whole participating SplitFedv3/v1 run as ONE program.

    The round body gathers the sampled slots' client segments (and their
    optimizer rows) out of the persistent ``[N, ...]`` stacks via
    ``slot_gid``, runs the synchronous slot-wide step scan (per-step DP
    keys fold in the GLOBAL hospital id through the step fn's ``gids``
    hook, so draws are co-sample independent), and scatters the trained
    rows back.  ``step_valid[E, steps]`` masks rounds whose sampled max
    batch count is below the global grid height.  Fixed-K only (every
    slot real), so the in-step server-gradient average is the plain mean
    over slots.  The Adam step count of the stacked client optimizer
    stays a single shared scalar (as in the non-participating engine) —
    it advances with the rounds regardless of who was sampled.  Returns
    ``run(stacked_clients[N,...], server, c_opt, s_opt,
    batches[E,S,NB,...], b_idx[E,steps,S], key_idx[E,steps],
    step_valid[E,steps], base_key, slot_gid[E,S])
    -> (..., [E, steps, S] losses)``.
    """
    step, keyed = sflv3_step_fn(adapter, opt_client, opt_server, k_slots,
                                transport, privacy)

    def rowwise(tree):
        """Leaves with a leading global-hospital axis (vs shared scalars
        like the Adam count)."""
        return jax.tree.map(
            lambda x: np.ndim(x) > 0 and np.shape(x)[0] == n_global, tree)

    def gather(tree, gid):
        return jax.tree.map(
            lambda x, r: x[gid] if r else x, tree, rowwise(tree))

    def scatter(full, rows, gid):
        return jax.tree.map(
            lambda x, y, r: x.at[gid].set(y) if r else y,
            full, rows, rowwise(full))

    def run(stacked_clients, server, c_opt, s_opt, batches, b_idx, key_idx,
            step_valid, base_key, slot_gid):
        def round_body(carry, xs):
            sc, sp, co, so = carry
            b_e, bi_e, ki_e, sv_e, gid_e = xs
            sck = gather(sc, gid_e)
            cok = gather(co, gid_e)

            def body(c2, xs2):
                sck_, sp_, cok_, so_ = c2
                bi, ki, sv = xs2
                batch = jax.tree.map(
                    lambda x: x[jnp.arange(k_slots), bi], b_e)
                out = step(sck_, sp_, cok_, so_, batch,
                           _step_key(base_key, ki, keyed), gid_e)
                new = tree_select(sv > 0, out[:4],
                                  (sck_, sp_, cok_, so_))
                return new, jnp.where(sv > 0, out[4], 0.0)

            (sck, sp, cok, so), losses = jax.lax.scan(
                body, (sck, sp, cok, so), (bi_e, ki_e, sv_e))
            sc = scatter(sc, sck, gid_e)
            co = scatter(co, cok, gid_e)
            if sync_clients:
                m = jax.tree.map(lambda x: x.mean(axis=0), sck)
                sc = jax.tree.map(
                    lambda x, mm: jnp.broadcast_to(mm[None], x.shape),
                    sc, m)
            return (sc, sp, co, so), losses

        carry, losses = jax.lax.scan(
            round_body, (stacked_clients, server, c_opt, s_opt),
            (batches, b_idx, key_idx, step_valid, slot_gid))
        return (*carry, losses)

    return _donating_jit(run, donate_argnums=(0, 1, 2, 3, 4))


# ---------------------------------------------------------------------------
# host-side helpers shared by the strategies' compiled run_epoch paths
# ---------------------------------------------------------------------------

def client_major_log(losses, packed: PackedEpoch):
    """Flatten a ``[C, NB]`` loss array in client-major valid order —
    exactly the stepwise FL/centralized loss ordering."""
    arr = np.asarray(losses)
    flat, weights = [], []
    for c, nb in enumerate(packed.n_batches):
        flat.extend(float(x) for x in arr[c, :nb])
        weights.extend(packed.step_examples[c])
    return flat, weights


def scheduled_log(losses, sched: np.ndarray, packed: PackedEpoch):
    """Per-step losses already in schedule order; weights follow the
    schedule's (client, batch) rows."""
    arr = np.asarray(losses)
    flat = [float(x) for x in arr]
    weights = [packed.step_examples[int(c)][int(b)] for c, b in sched]
    return flat, weights


def key_index_grid(strategy, packed: PackedEpoch) -> np.ndarray:
    """[C, NB] per-step key indices in client-major stepwise order (FL)."""
    grid = np.zeros((len(packed.n_batches), packed.nb_max), np.uint32)
    if strategy._keyed:
        for c, nb in enumerate(packed.n_batches):
            grid[c, :nb] = strategy._take_key_indices(nb)
    return grid
