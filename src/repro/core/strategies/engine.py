"""Compiled multi-hospital execution engine.

The stepwise engine (the legacy path in each strategy, kept as the parity
reference) dispatches one jitted step per mini-batch per hospital from a
Python host loop — wall-clock is dominated by dispatch overhead and
hospitals run strictly sequentially.  This module lowers a WHOLE epoch into
a single XLA program instead:

  * **pad-and-mask layout** — each hospital's shuffled epoch is packed into
    rectangular ``[n_clients, n_batches, batch, ...]`` arrays plus a
    ``[n_clients, n_batches]`` validity mask; uneven hospital sizes become
    masked (no-op) scan steps and, with ``drop_remainder=False``, the final
    short batch becomes per-example weights instead of a ragged shape.
  * **scan over batches, vmap over hospitals** where semantics allow it:
    FL local epochs are independent per hospital, so the per-client
    ``lax.scan`` is wrapped in a ``vmap`` over the stacked hospital axis.
  * **scanned interleave** where they don't: the SL/SFLv2 server segment
    (and its Adam state) is shared and updated sequentially in schedule
    order, so the epoch is ONE ``lax.scan`` over the dense
    ``[step] -> (client, batch)`` schedule array from
    ``repro.core.schedule.schedule_array`` — exact sequential Adam
    semantics, zero host dispatches.
  * **per-step PRNG keys by fold-in on the scan index**: the stepwise path
    draws key ``fold_in(base, t)`` for the t-th step of the run; the packer
    reserves the same running counter (``Strategy._take_key_indices``) and
    the scan body folds the reserved index in, so DP-SGD / cut-layer noise
    draws are bit-identical across engines.

Every scan body calls the SAME pure step functions
(``repro.core.strategies.base.{full,split,sflv3}_step_fn``) the stepwise
jit wrappers use, which is what makes the two engines numerically
equivalent (asserted at 1e-5 in tests/test_engine.py).

``maybe_shard`` optionally places the hospital axis across local devices
(``jax.sharding``); on a single device it is a no-op, so the engine runs
unchanged on one CPU and scales to a multi-device host.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import (SplitAdapter, tree_put, tree_select,
                                  tree_take)
from repro.core.strategies.base import (full_step_fn, sflv3_step_fn,
                                        split_step_fn)
from repro import optim as O


# ---------------------------------------------------------------------------
# pad-and-mask epoch packing
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PackedEpoch:
    """One epoch of every hospital's data in rectangular form.

    ``batches[k]`` has shape ``[n_clients, nb_max, batch, ...]``; rows past
    a hospital's real data are zero padding flagged invalid by ``mask``.
    ``ex_weights`` (only with ``drop_remainder=False``) carries per-example
    validity for the final short batch of each hospital.
    """
    batches: dict
    mask: np.ndarray                       # [C, NB] bool
    ex_weights: np.ndarray | None          # [C, NB, B] float32
    n_batches: list
    step_examples: list                    # per client: valid-example counts
    n_samples: list
    batch_size: int

    @property
    def nb_max(self) -> int:
        return self.mask.shape[1]

    @property
    def total_steps(self) -> int:
        return int(sum(self.n_batches))


def pack_epoch(client_data: list, batch_size: int,
               rng: np.random.Generator | None,
               drop_remainder: bool = True) -> PackedEpoch:
    """Shuffle + pack every hospital's epoch (mirrors ``np_batches``).

    The per-client shuffles consume ``rng`` in hospital order — exactly the
    draws the stepwise path makes — so both engines train on identical
    batch compositions.
    """
    n_batches, n_samples, step_examples, order = [], [], [], []
    for d in client_data:
        n = len(next(iter(d.values())))
        idx = np.arange(n)
        if rng is not None:
            rng.shuffle(idx)
        nb_full, rem = divmod(n, batch_size)
        nb = nb_full + (1 if rem and not drop_remainder else 0)
        order.append(idx)
        n_batches.append(nb)
        n_samples.append(n)
        step_examples.append([batch_size] * nb_full
                             + ([rem] if nb > nb_full else []))
    C, NB = len(client_data), max(n_batches, default=0)

    batches = {}
    for k in client_data[0]:
        proto = client_data[0][k]
        out = np.zeros((C, NB * batch_size, *proto.shape[1:]), proto.dtype)
        for c, d in enumerate(client_data):
            used = (n_batches[c] * batch_size if drop_remainder
                    else n_samples[c])
            out[c, :used] = d[k][order[c][:used]]
        batches[k] = out.reshape(C, NB, batch_size, *proto.shape[1:])

    mask = np.zeros((C, NB), bool)
    ex_w = (None if drop_remainder
            else np.zeros((C, NB, batch_size), np.float32))
    for c in range(C):
        mask[c, :n_batches[c]] = True
        if ex_w is not None:
            for j, m in enumerate(step_examples[c]):
                ex_w[c, j, :m] = 1.0
    return PackedEpoch(batches, mask, ex_w, n_batches, step_examples,
                       n_samples, batch_size)


# ---------------------------------------------------------------------------
# optional hospital-axis sharding
# ---------------------------------------------------------------------------

def maybe_shard(tree, n_clients: int, enabled: bool = True):
    """Place every ``[n_clients, ...]`` leaf across the local devices along
    the hospital axis.  Single device (or a hospital count that does not
    divide the device count): no-op — the engine's single-device fallback."""
    devs = jax.devices()
    if not enabled or len(devs) < 2 or n_clients % len(devs) != 0:
        return tree
    from jax.sharding import Mesh, NamedSharding, PartitionSpec
    mesh = Mesh(np.asarray(devs), ("hosp",))
    spec = NamedSharding(mesh, PartitionSpec("hosp"))

    def put(x):
        if getattr(x, "ndim", 0) >= 1 and x.shape[0] == n_clients:
            return jax.device_put(x, spec)
        return x

    return jax.tree.map(put, tree)


# ---------------------------------------------------------------------------
# compiled epoch kernels
# ---------------------------------------------------------------------------

def _step_key(base_key, idx, keyed):
    if not keyed:
        return None
    from repro.privacy.dpsgd import step_key
    return step_key(base_key, idx)


def make_fl_epoch(adapter: SplitAdapter, opt: O.Optimizer, privacy=None):
    """FL round as vmap-over-hospitals of scan-over-batches.

    Every hospital starts from the broadcast global params with a fresh
    optimizer (FedAvg semantics); masked steps are no-ops via
    ``tree_select`` so the Adam step counter never advances on padding.
    Returns ``epoch(global_params, batches, mask, ex_w, key_idx, base_key)
    -> (stacked local params, [C, NB] losses)``.
    """
    step, keyed = full_step_fn(adapter, opt, privacy)

    def epoch(global_params, batches, mask, ex_w, key_idx, base_key):
        def per_client(b_c, m_c, w_c, ki_c):
            def body(carry, xs):
                p, s = carry
                batch, m, w, ki = xs
                p2, s2, loss = step(p, s, batch,
                                    _step_key(base_key, ki, keyed), w)
                return (tree_select(m, p2, p), tree_select(m, s2, s)), loss

            (p, _), losses = jax.lax.scan(
                body, (global_params, opt.init(global_params)),
                (b_c, m_c, w_c, ki_c))
            return p, losses

        return jax.vmap(per_client)(batches, mask, ex_w, key_idx)

    return jax.jit(epoch)


def make_seq_epoch(adapter: SplitAdapter, opt: O.Optimizer, privacy=None):
    """Centralized epoch as a single scan-over-batches (one 'hospital',
    persistent optimizer state).  Returns ``epoch(params, opt_state,
    batches, mask, ex_w, key_idx, base_key) -> (params, opt_state,
    [NB] losses)``."""
    step, keyed = full_step_fn(adapter, opt, privacy)

    def epoch(params, opt_state, batches, mask, ex_w, key_idx, base_key):
        def body(carry, xs):
            p, s = carry
            batch, m, w, ki = xs
            p2, s2, loss = step(p, s, batch, _step_key(base_key, ki, keyed),
                                w)
            return (tree_select(m, p2, p), tree_select(m, s2, s)), loss

        (params, opt_state), losses = jax.lax.scan(
            body, (params, opt_state), (batches, mask, ex_w, key_idx))
        return params, opt_state, losses

    return jax.jit(epoch)


def make_interleaved_epoch(adapter: SplitAdapter, opt_client: O.Optimizer,
                           opt_server: O.Optimizer, transport=None,
                           privacy=None):
    """SL/SFLv2 epoch as ONE scan over the dense schedule array.

    The shared server segment forces sequential semantics: each scan step
    gathers client ``c``'s segment + optimizer slice from the stacked
    hospital axis, runs the exact split step, and scatters the update back.
    Returns ``epoch(stacked_clients, server, stacked_c_opts, s_opt,
    batches, ex_w, sched, key_idx, base_key) -> (stacked_clients, server,
    stacked_c_opts, s_opt, [steps] losses)``.
    """
    step, keyed = split_step_fn(adapter, opt_client, opt_server, transport,
                                privacy)

    def epoch(stacked_clients, server, stacked_c_opts, s_opt, batches,
              ex_w, sched, key_idx, base_key):
        def body(carry, xs):
            sc, sp, co, so = carry
            cb, ki = xs
            c, b = cb[0], cb[1]
            batch = jax.tree.map(lambda x: x[c, b], batches)
            w = None if ex_w is None else ex_w[c, b]
            cp, sp, cop, so, loss = step(
                tree_take(sc, c), sp, tree_take(co, c), so, batch,
                _step_key(base_key, ki, keyed), w)
            return (tree_put(sc, c, cp), sp, tree_put(co, c, cop), so), loss

        carry, losses = jax.lax.scan(
            body, (stacked_clients, server, stacked_c_opts, s_opt),
            (sched, key_idx))
        return (*carry, losses)

    return jax.jit(epoch)


def make_sflv3_epoch(adapter: SplitAdapter, opt_client: O.Optimizer,
                     opt_server: O.Optimizer, n_clients: int, transport=None,
                     privacy=None):
    """SplitFedv3 epoch: scan over synchronous steps, vmap over hospitals
    inside each step (the step fn already vmaps), with the wrap-around
    batch index precomputed as a dense ``[steps, n_clients]`` array.
    Returns ``epoch(stacked_clients, server, c_opt, s_opt, batches, b_idx,
    key_idx, base_key) -> (..., [steps, C] losses)``."""
    step, keyed = sflv3_step_fn(adapter, opt_client, opt_server, n_clients,
                                transport, privacy)

    def epoch(stacked_clients, server, c_opt, s_opt, batches, b_idx,
              key_idx, base_key):
        def body(carry, xs):
            sc, sp, co, so = carry
            bi, ki = xs
            batch = jax.tree.map(
                lambda x: x[jnp.arange(n_clients), bi], batches)
            sc, sp, co, so, losses = step(
                sc, sp, co, so, batch, _step_key(base_key, ki, keyed))
            return (sc, sp, co, so), losses

        carry, losses = jax.lax.scan(
            body, (stacked_clients, server, c_opt, s_opt), (b_idx, key_idx))
        return (*carry, losses)

    return jax.jit(epoch)


@jax.jit
def stacked_weighted_mean(stacked, weights):
    """Data-size-weighted FedAvg over the leading hospital axis — ONE
    fused program instead of per-leaf eager host ops over a list of
    trees (host-side aggregation cost grows with n_clients x n_leaves
    and was dwarfing the compiled epoch itself)."""
    w = weights.astype(jnp.float32) / weights.astype(jnp.float32).sum()

    def leaf(x):
        wx = w.reshape((-1,) + (1,) * (x.ndim - 1))
        return (x.astype(jnp.float32) * wx).sum(axis=0).astype(x.dtype)

    return jax.tree.map(leaf, stacked)


@jax.jit
def stacked_mean_sync(stacked):
    """SFLv2-style client synchronization on the stacked hospital axis:
    every hospital gets the mean of all client segments."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x.mean(axis=0, keepdims=True), x.shape),
        stacked)


# ---------------------------------------------------------------------------
# host-side helpers shared by the strategies' compiled run_epoch paths
# ---------------------------------------------------------------------------

def client_major_log(losses, packed: PackedEpoch):
    """Flatten a ``[C, NB]`` loss array in client-major valid order —
    exactly the stepwise FL/centralized loss ordering."""
    arr = np.asarray(losses)
    flat, weights = [], []
    for c, nb in enumerate(packed.n_batches):
        flat.extend(float(x) for x in arr[c, :nb])
        weights.extend(packed.step_examples[c])
    return flat, weights


def scheduled_log(losses, sched: np.ndarray, packed: PackedEpoch):
    """Per-step losses already in schedule order; weights follow the
    schedule's (client, batch) rows."""
    arr = np.asarray(losses)
    flat = [float(x) for x in arr]
    weights = [packed.step_examples[int(c)][int(b)] for c, b in sched]
    return flat, weights


def key_index_grid(strategy, packed: PackedEpoch) -> np.ndarray:
    """[C, NB] per-step key indices in client-major stepwise order (FL)."""
    grid = np.zeros((len(packed.n_batches), packed.nb_max), np.uint32)
    if strategy._keyed:
        for c, nb in enumerate(packed.n_batches):
            grid[c, :nb] = strategy._take_key_indices(nb)
    return grid
