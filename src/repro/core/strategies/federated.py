"""Federated learning with FedAvg (paper §1.1/§3.3).

One federated round == one epoch (as in the paper): the global model is
pushed to every client, each client runs one local epoch with its own Adam,
and the server aggregates the resulting parameters with a data-size-weighted
average (McMahan et al. federated averaging).

With ``privacy.dp_enabled`` every local step uses the DP-SGD estimator and
each hospital's accountant composes over its own rounds; with
``privacy.secagg`` the aggregation runs through pairwise-mask secure
aggregation (``repro.privacy.secagg``) — the server only ever adds
uniformly-masked fixed-point uploads, and the handshake + masked-upload
bytes are metered.

Local epochs are independent across hospitals, so the compiled engine runs
the whole round as ONE program: ``vmap`` over the hospital axis of a
``lax.scan`` over each hospital's padded batch grid.  A multi-round
``run(n_epochs)`` goes further and folds the weighted FedAvg aggregation
into an outer scan over rounds — the whole training run is one XLA call.
Secure aggregation keeps the per-round path: its masked uploads are a
host-side protocol and cannot be fused into the program.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import unstack_tree
from repro.core.strategies.base import (Strategy, EpochLog, make_full_step,
                                        np_batches, tree_weighted_mean)


class FedAvg(Strategy):
    name = "fl"
    shared_eval_params = True

    def setup(self, key):
        params = self.adapter.init(key)
        if not hasattr(self, "_opt"):
            self._opt = self.opt_factory()
            self._step = make_full_step(self.adapter, self._opt,
                                        self.privacy)
        if (self.privacy is not None and self.privacy.secagg
                and not hasattr(self, "secagg")):
            from repro.privacy.secagg import SecAgg
            self.secagg = SecAgg(self.n_clients, seed=self.privacy.seed)
        return {"params": params}

    def _aggregate(self, locals_, weights):
        if self.privacy is not None and self.privacy.secagg:
            host = [jax.tree.map(np.asarray, t) for t in locals_]
            agg = self.secagg.aggregate_weighted(host, weights)
            return jax.tree.map(lambda a, old: jnp.asarray(a, old.dtype),
                                agg, locals_[0])
        return tree_weighted_mean(locals_, weights)

    def _round_telemetry(self, tel, losses, metrics, mask, old_gp,
                         stacked_locals, new_gp):
        """Reduce one FL round's ``[C, NB]`` stacks (+ the in-round update
        cosine from the stacked locals) into a RoundTelemetry."""
        from repro.core.strategies import engine as ENG
        from repro.obs import telemetry as T
        extra = None
        if tel.update_cosine:
            cos = np.asarray(ENG.update_cosine(stacked_locals, old_gp,
                                               new_gp))
            extra = {"update_cosine": cos[None]}
        metrics = {k: np.asarray(v)[None] for k, v in metrics.items()}
        return T.rounds_client_major(tel, np.asarray(losses)[None], metrics,
                                     mask, self.n_clients, extra)[0]

    def run_epoch(self, state, client_data, rng, batch_size):
        if self.engine == "compiled":
            return self._run_epoch_compiled(state, client_data, rng,
                                            batch_size)
        tel = self._tel
        step = self._step if tel is None else self._get_obs(
            "_step_obs", tel,
            lambda: make_full_step(self.adapter, self._opt, self.privacy,
                                   tel))
        locals_, weights, losses = [], [], []
        loss_w, client_steps, met_vals = [], [], []
        for ci, data in enumerate(client_data):
            p = state["params"]                    # start from global
            opt_state = self._opt.init(p)          # fresh optimizer per round
            n = len(data["label"])
            steps = 0
            for batch in np_batches(data, batch_size, rng,
                                    self.drop_remainder):
                args = ((p, opt_state, batch, self._next_key())
                        if self._keyed else (p, opt_state, batch))
                out = step(*args)
                self._count_dispatch()
                p, opt_state, loss = out[0], out[1], out[2]
                if tel is not None:
                    met_vals.append(out[3])
                losses.append(float(loss))
                loss_w.append(len(batch["label"]))
                steps += 1
                self._dp_account(ci, n, batch_size)
            locals_.append(p)
            weights.append(n)
            client_steps.append(steps)
        old_gp = state["params"]
        state["params"] = self._aggregate(locals_, weights)
        log = EpochLog(losses, len(losses), weights=loss_w,
                       client_steps=client_steps)
        if tel is not None:
            from repro.core.partition import stack_trees
            from repro.obs import telemetry as T
            arr, mask = T.pack_client_major(losses, client_steps)
            metrics = {
                k: T.pack_client_major([float(m[k]) for m in met_vals],
                                       client_steps)[0]
                for k in (met_vals[0] if met_vals else {})}
            log.telemetry = self._round_telemetry(
                tel, arr, metrics, mask, old_gp, stack_trees(locals_),
                state["params"])
        return state, log

    def _run_epoch_compiled(self, state, client_data, rng, batch_size):
        from repro.core.strategies import engine as ENG
        tel = self._tel
        place = self.placement
        with self._span("pack"):
            packed = ENG.pack_epoch(client_data, batch_size, rng,
                                    self.drop_remainder,
                                    pad_clients=place.n_pad)
        if packed.nb_max == 0:
            return state, EpochLog([], 0,
                                   client_steps=[0] * self.n_clients)
        if tel is None:
            if not hasattr(self, "_epoch_c"):
                self._epoch_c = ENG.make_fl_epoch(self.adapter, self._opt,
                                                  self.privacy, place)
            epoch_fn = self._epoch_c
        else:
            epoch_fn = self._get_obs(
                "_epoch_obs_c", tel,
                lambda: ENG.make_fl_epoch(self.adapter, self._opt,
                                          self.privacy, place, tel))
        key_idx = place.put(ENG.key_index_grid(self, packed))
        batches = place.put(packed.batches)
        with self._span("dispatch"):
            out = epoch_fn(
                state["params"], batches, place.put(packed.mask),
                place.put(packed.ex_weights), key_idx,
                self._privacy_base_key())
        self._count_dispatch()
        locals_stacked, losses = out[0], out[1]
        old_gp = state["params"]
        if self.privacy is not None and self.privacy.secagg:
            # secagg masks per-client host uploads: unstack (real hospitals
            # only) and reuse the exact stepwise aggregation path
            locals_ = unstack_tree(locals_stacked, self.n_clients)
            state["params"] = self._aggregate(
                locals_, packed.n_samples[:self.n_clients])
        else:
            state["params"] = ENG.stacked_weighted_mean(
                locals_stacked, np.asarray(packed.n_samples, np.float32))
        flat, loss_w = ENG.client_major_log(losses, packed)
        for ci, nb in enumerate(packed.n_batches):
            if nb:
                self._dp_account(ci, packed.n_samples[ci], batch_size,
                                 count=nb)
        log = EpochLog(flat, len(flat), weights=loss_w,
                       client_steps=list(
                           packed.n_batches[:self.n_clients]))
        if tel is not None:
            log.telemetry = self._round_telemetry(
                tel, losses, {k: np.asarray(v) for k, v in out[2].items()},
                packed.mask, old_gp, locals_stacked, state["params"])
        return state, log

    @property
    def _whole_run(self):
        # secagg aggregates host-side per-round (masked uploads) and keeps
        # the per-epoch dispatch path
        return not (self.privacy is not None and self.privacy.secagg)

    def _run_compiled(self, state, client_data, rng, batch_size, n_epochs):
        from repro.core.strategies import engine as ENG
        if ENG.empty_run(client_data, batch_size, self.drop_remainder):
            return None                        # empty run: per-epoch path
        tel = self._tel
        place = self.placement
        with self._span("pack"):
            batches, packed = ENG.pack_run(client_data, batch_size, rng,
                                           n_epochs, self.drop_remainder,
                                           pad_clients=place.n_pad)
        if tel is None:
            if not hasattr(self, "_run_c"):
                self._run_c = ENG.make_fl_run(self.adapter, self._opt,
                                              self.privacy, place)
            run_fn = self._run_c
        else:
            run_fn = self._get_obs(
                "_run_obs_c", tel,
                lambda: ENG.make_fl_run(self.adapter, self._opt,
                                        self.privacy, place, tel))
        key_idx = np.stack([ENG.key_index_grid(self, packed)
                            for _ in range(n_epochs)])
        args = (state["params"], place.put(batches, axis=1),
                place.put(packed.mask), place.put(packed.ex_weights),
                place.put(key_idx, axis=1), self._privacy_base_key(),
                np.asarray(packed.n_samples, np.float32))
        with self._span("dispatch"):
            if tel is None:
                state["params"], losses = run_fn(*args)
            else:
                state["params"], (losses, met) = run_fn(*args)
        self._count_dispatch()
        self._last_run_invocation = (run_fn, ENG.abstract_args(args))
        self._run_calls = getattr(self, "_run_calls", 0) + 1
        losses = np.asarray(losses)
        logs = []
        for e in range(n_epochs):
            flat, loss_w = ENG.client_major_log(losses[e], packed)
            logs.append(EpochLog(flat, len(flat), weights=loss_w,
                                 client_steps=list(
                                     packed.n_batches[:self.n_clients])))
        if tel is not None:
            from repro.obs import telemetry as T
            met = {k: np.asarray(v) for k, v in met.items()}
            extra = ({"update_cosine": met.pop("update_cosine")}
                     if "update_cosine" in met else None)
            rounds = T.rounds_client_major(tel, losses, met, packed.mask,
                                           self.n_clients, extra)
            for log, r in zip(logs, rounds):
                log.telemetry = r
        for ci, nb in enumerate(packed.n_batches):
            if nb:
                self._dp_account(ci, packed.n_samples[ci], batch_size,
                                 count=nb * n_epochs)
        return state, logs

    def params_for_eval(self, state, client_idx):
        return state["params"]
