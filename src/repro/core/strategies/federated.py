"""Federated learning with FedAvg (paper §1.1/§3.3).

One federated round == one epoch (as in the paper): the global model is
pushed to every client, each client runs one local epoch with its own Adam,
and the server aggregates the resulting parameters with a data-size-weighted
average (McMahan et al. federated averaging).

With ``privacy.dp_enabled`` every local step uses the DP-SGD estimator and
each hospital's accountant composes over its own rounds; with
``privacy.secagg`` the aggregation runs through pairwise-mask secure
aggregation (``repro.privacy.secagg``) — the server only ever adds
uniformly-masked fixed-point uploads, and the handshake + masked-upload
bytes are metered.

Local epochs are independent across hospitals, so the compiled engine runs
the whole round as ONE program: ``vmap`` over the hospital axis of a
``lax.scan`` over each hospital's padded batch grid.  A multi-round
``run(n_epochs)`` goes further and folds the weighted FedAvg aggregation
into an outer scan over rounds — the whole training run is one XLA call.
Secure aggregation keeps the per-round path: its masked uploads are a
host-side protocol and cannot be fused into the program.
"""

from __future__ import annotations

import numpy as np

from repro.core import aggregate as AGG
from repro.core.strategies.base import (Strategy, EpochLog, make_full_step,
                                        np_batches)


class FedAvg(Strategy):
    name = "fl"
    shared_eval_params = True

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self._secagg_on = (self.privacy is not None and self.privacy.secagg)
        if self._secagg_on:
            if self.aggregator_spec is not None:
                raise ValueError("aggregator= cannot be combined with "
                                 "privacy.secagg (secure aggregation IS "
                                 "the aggregation rule)")
            if self.participation is not None:
                raise ValueError("participation= with privacy.secagg is "
                                 "not supported (the pairwise-mask "
                                 "protocol assumes a fixed cohort)")
            self._agg = None                 # built in setup (needs SecAgg)
        else:
            self._agg = AGG.make_aggregator(self.aggregator_spec)

    def setup(self, key):
        params = self.adapter.init(key)
        if not hasattr(self, "_opt"):
            self._opt = self.opt_factory()
            self._step = make_full_step(self.adapter, self._opt,
                                        self.privacy)
        if self._secagg_on and not hasattr(self, "secagg"):
            from repro.privacy.secagg import SecAgg
            self.secagg = SecAgg(self.n_clients, seed=self.privacy.seed)
            self._agg = AGG.SecAggregator(self.secagg)
        return {"params": params}

    def _aggregate(self, locals_, weights, prev=None):
        return self._agg.aggregate_trees(locals_, weights, prev)

    def _round_telemetry(self, tel, losses, metrics, mask, old_gp,
                         stacked_locals, new_gp):
        """Reduce one FL round's ``[C, NB]`` stacks (+ the in-round update
        cosine from the stacked locals) into a RoundTelemetry."""
        from repro.core.strategies import engine as ENG
        from repro.obs import telemetry as T
        extra = None
        if tel.update_cosine:
            cos = np.asarray(ENG.update_cosine(stacked_locals, old_gp,
                                               new_gp))
            extra = {"update_cosine": cos[None]}
        metrics = {k: np.asarray(v)[None] for k, v in metrics.items()}
        return T.rounds_client_major(tel, np.asarray(losses)[None], metrics,
                                     mask, self.n_clients, extra)[0]

    def run_epoch(self, state, client_data, rng, batch_size):
        if self.engine == "compiled":
            return self._run_epoch_compiled(state, client_data, rng,
                                            batch_size)
        tel = self._tel
        step = self._step if tel is None else self._get_obs(
            "_step_obs", tel,
            lambda: make_full_step(self.adapter, self._opt, self.privacy,
                                   tel))
        locals_, weights, losses = [], [], []
        loss_w, client_steps, met_vals = [], [], []
        for ci, data in enumerate(client_data):
            p = state["params"]                    # start from global
            opt_state = self._opt.init(p)          # fresh optimizer per round
            n = len(data["label"])
            steps = 0
            for batch in np_batches(data, batch_size, rng,
                                    self.drop_remainder):
                args = ((p, opt_state, batch, self._next_key())
                        if self._keyed else (p, opt_state, batch))
                out = step(*args)
                self._count_dispatch()
                p, opt_state, loss = out[0], out[1], out[2]
                if tel is not None:
                    met_vals.append(out[3])
                losses.append(float(loss))
                loss_w.append(len(batch["label"]))
                steps += 1
                self._dp_account(ci, n, batch_size)
            locals_.append(p)
            weights.append(n)
            client_steps.append(steps)
        old_gp = state["params"]
        state["params"] = self._aggregate(locals_, weights, prev=old_gp)
        log = EpochLog(losses, len(losses), weights=loss_w,
                       client_steps=client_steps)
        if tel is not None:
            from repro.core.partition import stack_trees
            from repro.obs import telemetry as T
            arr, mask = T.pack_client_major(losses, client_steps)
            metrics = {
                k: T.pack_client_major([float(m[k]) for m in met_vals],
                                       client_steps)[0]
                for k in (met_vals[0] if met_vals else {})}
            log.telemetry = self._round_telemetry(
                tel, arr, metrics, mask, old_gp, stack_trees(locals_),
                state["params"])
        return state, log

    def _run_epoch_compiled(self, state, client_data, rng, batch_size):
        from repro.core.strategies import engine as ENG
        tel = self._tel
        place = self.placement
        with self._span("pack"):
            packed = ENG.pack_epoch(client_data, batch_size, rng,
                                    self.drop_remainder,
                                    pad_clients=place.n_pad)
        if packed.nb_max == 0:
            return state, EpochLog([], 0,
                                   client_steps=[0] * self.n_clients)
        if tel is None:
            if not hasattr(self, "_epoch_c"):
                self._epoch_c = ENG.make_fl_epoch(self.adapter, self._opt,
                                                  self.privacy, place)
            epoch_fn = self._epoch_c
        else:
            epoch_fn = self._get_obs(
                "_epoch_obs_c", tel,
                lambda: ENG.make_fl_epoch(self.adapter, self._opt,
                                          self.privacy, place, tel))
        key_idx = place.put(ENG.key_index_grid(self, packed))
        batches = place.put(packed.batches)
        with self._span("dispatch"):
            out = epoch_fn(
                state["params"], batches, place.put(packed.mask),
                place.put(packed.ex_weights), key_idx,
                self._privacy_base_key())
        self._count_dispatch()
        locals_stacked, losses = out[0], out[1]
        old_gp = state["params"]
        # the aggregator's host path: the default WeightedMean dispatches
        # the exact pre-refactor jitted weighted mean; SecAggregator
        # unstacks real hospitals and runs the masked-upload protocol
        state["params"] = self._agg.host(
            locals_stacked, np.asarray(packed.n_samples, np.float32),
            prev=old_gp)
        flat, loss_w = ENG.client_major_log(losses, packed)
        for ci, nb in enumerate(packed.n_batches):
            if nb:
                self._dp_account(ci, packed.n_samples[ci], batch_size,
                                 count=nb)
        log = EpochLog(flat, len(flat), weights=loss_w,
                       client_steps=list(
                           packed.n_batches[:self.n_clients]))
        if tel is not None:
            log.telemetry = self._round_telemetry(
                tel, losses, {k: np.asarray(v) for k, v in out[2].items()},
                packed.mask, old_gp, locals_stacked, state["params"])
        return state, log

    @property
    def _whole_run(self):
        # secagg aggregates host-side per-round (masked uploads) and keeps
        # the per-epoch dispatch path; so does any non-scan-compatible
        # custom aggregator
        if self._secagg_on:
            return False
        return self._agg.scan_compatible

    @property
    def _run_aggregator(self):
        """Aggregator passed into the whole-run builders: ``None`` for the
        default weighted mean so the fused program traces byte-identical
        to the pre-aggregator engine."""
        return None if type(self._agg) is AGG.WeightedMean else self._agg

    def _run_compiled(self, state, client_data, rng, batch_size, n_epochs):
        from repro.core.strategies import engine as ENG
        if ENG.empty_run(client_data, batch_size, self.drop_remainder):
            return None                        # empty run: per-epoch path
        if self.participation is not None:
            return self._run_participation(state, client_data, rng,
                                           batch_size, n_epochs)
        tel = self._tel
        place = self.placement
        with self._span("pack"):
            batches, packed = ENG.pack_run(client_data, batch_size, rng,
                                           n_epochs, self.drop_remainder,
                                           pad_clients=place.n_pad)
        if tel is None:
            if not hasattr(self, "_run_c"):
                self._run_c = ENG.make_fl_run(
                    self.adapter, self._opt, self.privacy, place,
                    aggregator=self._run_aggregator)
            run_fn = self._run_c
        else:
            run_fn = self._get_obs(
                "_run_obs_c", tel,
                lambda: ENG.make_fl_run(self.adapter, self._opt,
                                        self.privacy, place, tel,
                                        aggregator=self._run_aggregator))
        key_idx = np.stack([ENG.key_index_grid(self, packed)
                            for _ in range(n_epochs)])
        args = (state["params"], place.put(batches, axis=1),
                place.put(packed.mask), place.put(packed.ex_weights),
                place.put(key_idx, axis=1), self._privacy_base_key(),
                np.asarray(packed.n_samples, np.float32))
        with self._span("dispatch"):
            if tel is None:
                state["params"], losses = run_fn(*args)
            else:
                state["params"], (losses, met) = run_fn(*args)
        self._count_dispatch()
        self._last_run_invocation = (run_fn, ENG.abstract_args(args))
        self._run_calls = getattr(self, "_run_calls", 0) + 1
        losses = np.asarray(losses)
        logs = []
        for e in range(n_epochs):
            flat, loss_w = ENG.client_major_log(losses[e], packed)
            logs.append(EpochLog(flat, len(flat), weights=loss_w,
                                 client_steps=list(
                                     packed.n_batches[:self.n_clients])))
        if tel is not None:
            from repro.obs import telemetry as T
            met = {k: np.asarray(v) for k, v in met.items()}
            extra = ({"update_cosine": met.pop("update_cosine")}
                     if "update_cosine" in met else None)
            rounds = T.rounds_client_major(tel, losses, met, packed.mask,
                                           self.n_clients, extra)
            for log, r in zip(logs, rounds):
                log.telemetry = r
        for ci, nb in enumerate(packed.n_batches):
            if nb:
                self._dp_account(ci, packed.n_samples[ci], batch_size,
                                 count=nb * n_epochs)
        return state, logs

    def _run_participation(self, state, client_data, rng, batch_size,
                           n_epochs):
        """Whole participating run: per-round K-of-N subsampling packed
        into a fixed slot axis, still ONE dispatch.

        The per-step key-index grid is laid out over the VIRTUAL full-N
        run — round r, hospital g, local step t gets the index the
        non-participating run would give it — so a hospital's DP/noise
        draws depend only on (round, hospital) and ``Participation(k=N)``
        reproduces ``participation=None`` exactly."""
        from repro.core.strategies import engine as ENG
        part = self.participation
        tel = self._tel
        with self._span("pack"):
            batches, pack = ENG.pack_participation_run(
                client_data, batch_size, rng, n_epochs, part,
                self.drop_remainder)
        nbs = pack.n_batches
        T_N = int(sum(nbs))
        prefix = np.concatenate([[0], np.cumsum(nbs)[:-1]]).astype(np.int64)
        key_idx = np.zeros((n_epochs, pack.n_slots, pack.nb_max), np.uint32)
        if self._keyed:
            base0 = self._key_step
            for e in range(n_epochs):
                for s in range(pack.n_slots):
                    g = int(pack.slot_gid[e, s])
                    if g >= 0 and nbs[g]:
                        key_idx[e, s, :nbs[g]] = (
                            base0 + 1 + e * T_N + prefix[g]
                            + np.arange(nbs[g], dtype=np.int64))
            self._key_step += n_epochs * T_N
        if tel is None:
            if not hasattr(self, "_run_part_c"):
                self._run_part_c = ENG.make_fl_run_participation(
                    self.adapter, self._opt, self.privacy,
                    aggregator=self._agg)
            run_fn = self._run_part_c
        else:
            run_fn = self._get_obs(
                "_run_part_obs_c", tel,
                lambda: ENG.make_fl_run_participation(
                    self.adapter, self._opt, self.privacy, tel,
                    aggregator=self._agg))
        args = (state["params"], batches, pack.mask, pack.ex_weights,
                key_idx, self._privacy_base_key(), pack.agg_w,
                pack.staleness, pack.slot_gid)
        with self._span("dispatch"):
            if tel is None:
                state["params"], losses = run_fn(*args)
            else:
                state["params"], (losses, met) = run_fn(*args)
        self._count_dispatch()
        self._last_run_invocation = (run_fn, ENG.abstract_args(args))
        self._run_calls = getattr(self, "_run_calls", 0) + 1
        losses = np.asarray(losses)
        logs = []
        for e in range(n_epochs):
            flat, loss_w = [], []
            csteps = [0] * pack.n_global
            for s in range(pack.n_slots):
                g = int(pack.slot_gid[e, s])
                if g < 0:
                    continue
                flat.extend(float(x) for x in losses[e, s, :nbs[g]])
                loss_w.extend(pack.step_examples[g])
                csteps[g] = nbs[g]
            logs.append(EpochLog(flat, len(flat), weights=loss_w,
                                 client_steps=csteps))
        if tel is not None:
            from repro.obs import telemetry as T
            met = {k: np.asarray(v) for k, v in met.items()}
            extra = ({"update_cosine": met.pop("update_cosine")}
                     if "update_cosine" in met else None)
            rounds = T.rounds_participation(tel, losses, met, pack, extra)
            for log, r in zip(logs, rounds):
                log.telemetry = r
        # RDP accounting: with sampling randomness EVERY hospital composes
        # EVERY round at the amplified rate (q_round * q_batch) over its
        # would-be step count; a deterministic schedule composes only the
        # realized rounds at the plain batch rate
        if part.kind == "schedule":
            for g in range(pack.n_global):
                cnt = int(pack.part_mask[:, g].sum()) * nbs[g]
                if cnt:
                    self._dp_account(g, pack.n_samples[g], batch_size,
                                     count=cnt)
        else:
            self._last_part_nbs = list(nbs)
            for g in range(pack.n_global):
                if nbs[g]:
                    self._dp_account(g, pack.n_samples[g], batch_size,
                                     count=nbs[g] * n_epochs,
                                     q_scale=part.rate)
        return state, logs

    def params_for_eval(self, state, client_idx):
        return state["params"]
