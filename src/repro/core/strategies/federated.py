"""Federated learning with FedAvg (paper §1.1/§3.3).

One federated round == one epoch (as in the paper): the global model is
pushed to every client, each client runs one local epoch with its own Adam,
and the server aggregates the resulting parameters with a data-size-weighted
average (McMahan et al. federated averaging).

With ``privacy.dp_enabled`` every local step uses the DP-SGD estimator and
each hospital's accountant composes over its own rounds; with
``privacy.secagg`` the aggregation runs through pairwise-mask secure
aggregation (``repro.privacy.secagg``) — the server only ever adds
uniformly-masked fixed-point uploads, and the handshake + masked-upload
bytes are metered.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.strategies.base import (Strategy, EpochLog, make_full_step,
                                        np_batches, tree_weighted_mean)


class FedAvg(Strategy):
    name = "fl"

    def setup(self, key):
        params = self.adapter.init(key)
        if not hasattr(self, "_opt"):
            self._opt = self.opt_factory()
            self._step = make_full_step(self.adapter, self._opt,
                                        self.privacy)
        if (self.privacy is not None and self.privacy.secagg
                and not hasattr(self, "secagg")):
            from repro.privacy.secagg import SecAgg
            self.secagg = SecAgg(self.n_clients, seed=self.privacy.seed)
        return {"params": params}

    def _aggregate(self, locals_, weights):
        if self.privacy is not None and self.privacy.secagg:
            host = [jax.tree.map(np.asarray, t) for t in locals_]
            agg = self.secagg.aggregate_weighted(host, weights)
            return jax.tree.map(lambda a, old: jnp.asarray(a, old.dtype),
                                agg, locals_[0])
        return tree_weighted_mean(locals_, weights)

    def run_epoch(self, state, client_data, rng, batch_size):
        locals_, weights, losses = [], [], []
        for ci, data in enumerate(client_data):
            p = state["params"]                    # start from global
            opt_state = self._opt.init(p)          # fresh optimizer per round
            n = len(data["label"])
            for batch in np_batches(data, batch_size, rng):
                if self._keyed:
                    p, opt_state, loss = self._step(p, opt_state, batch,
                                                    self._next_key())
                else:
                    p, opt_state, loss = self._step(p, opt_state, batch)
                losses.append(float(loss))
                self._dp_account(ci, n, batch_size)
            locals_.append(p)
            weights.append(n)
        state["params"] = self._aggregate(locals_, weights)
        return state, EpochLog(losses, len(losses))

    def params_for_eval(self, state, client_idx):
        return state["params"]
