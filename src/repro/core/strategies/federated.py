"""Federated learning with FedAvg (paper §1.1/§3.3).

One federated round == one epoch (as in the paper): the global model is
pushed to every client, each client runs one local epoch with its own Adam,
and the server aggregates the resulting parameters with a data-size-weighted
average (McMahan et al. federated averaging).
"""

from __future__ import annotations


from repro.core.strategies.base import (Strategy, EpochLog, make_full_step,
                                        np_batches, tree_weighted_mean)


class FedAvg(Strategy):
    name = "fl"

    def setup(self, key):
        params = self.adapter.init(key)
        if not hasattr(self, "_opt"):
            self._opt = self.opt_factory()
            self._step = make_full_step(self.adapter, self._opt)
        return {"params": params}

    def run_epoch(self, state, client_data, rng, batch_size):
        locals_, weights, losses = [], [], []
        for ci, data in enumerate(client_data):
            p = state["params"]                    # start from global
            opt_state = self._opt.init(p)          # fresh optimizer per round
            for batch in np_batches(data, batch_size, rng):
                p, opt_state, loss = self._step(p, opt_state, batch)
                losses.append(float(loss))
            locals_.append(p)
            weights.append(len(data["label"]))
        state["params"] = tree_weighted_mean(locals_, weights)
        return state, EpochLog(losses, len(losses))

    def params_for_eval(self, state, client_idx):
        return state["params"]
