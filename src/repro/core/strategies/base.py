"""Strategy API + shared step builders.

Every strategy consumes a ``SplitAdapter`` (architecture-agnostic) and an
optimizer factory, and exposes:

    setup(key)                        -> state
    run_epoch(state, client_data, rng, batch_size) -> (state, log)
    scores(state, client_idx, data, batch_size)    -> per-sample scores

``client_data`` is a list (len n_clients) of dicts of numpy arrays.
Evaluation follows the paper (§3.4): a sample from hospital i always passes
through hospital i's own client segment(s); FL/centralized have one model.

Two execution engines share the SAME pure step functions (``full_step_fn``
/ ``split_step_fn`` / ``sflv3_step_fn``):

  * ``compiled`` (the DEFAULT; repro.core.strategies.engine): whole epochs
    — and whole multi-epoch runs via ``Strategy.run`` — lowered to single
    XLA programs: ``lax.scan`` over batches (and rounds), ``vmap`` over
    the hospital axis where semantics allow.
  * ``stepwise`` (legacy; kept as the parity oracle): a Python host loop
    dispatching one jitted step per mini-batch.

Because both engines trace the identical step math, they agree to float32
round-off (asserted at 1e-5 in tests/test_engine.py).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import numpy as np

from repro.core.partition import SplitAdapter, stack_trees, unstack_tree
from repro import optim as O


@dataclasses.dataclass
class EpochLog:
    """Per-epoch training log.

    ``weights`` are per-step valid-example counts (None == every step saw
    a full batch); ``mean_loss`` is the example-weighted mean so a compiled
    (pad-and-mask) epoch and a stepwise epoch over the same data report
    identical statistics.  ``client_steps`` counts optimizer steps actually
    attributed to each hospital (masked padding steps excluded).
    """
    losses: list
    steps: int
    weights: list | None = None
    client_steps: list[int] | None = None
    # repro.obs.telemetry.RoundTelemetry when the epoch ran observed
    # (typed loosely so the strategy layer never hard-imports repro.obs)
    telemetry: object = None

    @property
    def mean_loss(self):
        if not self.losses:
            return float("nan")
        if self.weights is None:
            return float(np.mean(self.losses))
        w = np.asarray(self.weights, dtype=np.float64)
        l = np.asarray(self.losses, dtype=np.float64)
        return float((l * w).sum() / max(w.sum(), 1.0))


# aggregation cores live in repro.core.aggregate (PR 9); re-exported here
# because the strategy layer and external callers import them from base
from repro.core.aggregate import tree_mean, tree_weighted_mean  # noqa: E402


def np_batches(data: dict, batch_size: int, rng: np.random.Generator | None,
               drop_remainder: bool = True):
    """Shuffle + slice a client's epoch into batch dicts.

    ``drop_remainder=True`` reproduces the paper testbed (and this repo's
    historical behaviour): the final ``n % batch_size`` samples are silently
    dropped.  ``drop_remainder=False`` keeps them as one short final batch —
    the stepwise counterpart of the compiled engine's pad-and-mask rows.
    """
    n = len(next(iter(data.values())))
    idx = np.arange(n)
    if rng is not None:
        rng.shuffle(idx)
    stop = (n // batch_size) * batch_size if drop_remainder else n
    return [{k: v[idx[s:s + batch_size]] for k, v in data.items()}
            for s in range(0, stop, batch_size)]


class Strategy:
    name: str = "base"
    # every hospital scores with the same params (FL/centralized): the
    # batched scorer keeps ONE param copy instead of an n_clients stack
    shared_eval_params: bool = False

    # centralized overrides: epsilon series composes at the pooled rate
    _eps_pooled: bool = False

    def __init__(self, adapter: SplitAdapter, opt_factory: Callable[[], O.Optimizer],
                 n_clients: int, privacy=None, engine: str = "compiled",
                 drop_remainder: bool = True, shard: bool = False,
                 observe=None, participation=None, aggregator=None):
        if engine not in ("stepwise", "compiled"):
            raise ValueError(f"unknown engine {engine!r}")
        self.adapter = adapter
        self.opt_factory = opt_factory
        self.n_clients = n_clients
        self.privacy = privacy          # repro.privacy.PrivacyConfig | None
        self.engine = engine
        self.drop_remainder = drop_remainder
        self.shard = shard              # place hospital axis across devices
        from repro.core.participation import as_participation
        self.participation = as_participation(participation)
        if self.participation is not None:
            if self.participation.n_global != n_clients:
                raise ValueError(
                    f"participation.n_global={self.participation.n_global} "
                    f"!= n_clients={n_clients}")
            if engine != "compiled":
                raise ValueError(
                    "participation= requires the compiled engine (the "
                    "stepwise oracle has no slot-packed hospital axis)")
            if shard:
                raise ValueError("participation= with shard= is not "
                                 "supported (slot axis vs mesh padding)")
        # aggregator spec (repro.core.aggregate); resolved by FedAvg —
        # make_strategy rejects it for every other method
        self.aggregator_spec = aggregator
        from repro.core.placement import Placement
        # pad-to-mesh hospital-axis placement (no-op mesh on one device;
        # the stepwise parity oracle never pads or shards)
        self.placement = Placement.make(
            n_clients, enabled=shard and engine == "compiled")
        self._accountants = None
        self._key_step = 0
        # observability (repro.obs): metric-tap spec, span tracer, and the
        # training-program dispatch counter — all inert when unused
        from repro.obs.telemetry import as_telemetry
        self.observe = as_telemetry(observe)
        self._tel_active = self.observe
        self._tracer = None
        self._dispatches = 0
        self.last_run_telemetry = None

    # -- to implement ---------------------------------------------------------
    def setup(self, key):
        raise NotImplementedError

    def run_epoch(self, state, client_data, rng, batch_size):
        raise NotImplementedError

    def params_for_eval(self, state, client_idx) -> dict:
        """Full param dict (all segments) used to score client ``client_idx``."""
        raise NotImplementedError

    # -- deployment (repro.serving) -------------------------------------------
    def export(self, state, client_idx: int = 0, meta: dict | None = None):
        """Materialize the deployable full model as a ``ServableModel``.

        FL/centralized export the one global tree (``client_idx`` is
        moot); the split family exports hospital ``client_idx``'s client
        segment(s) stitched with the shared server segment at the cut —
        the exact composition ``params_for_eval`` scores with, so the
        export's scores are bit-identical to this strategy's eval
        (``ServableModel.scores`` replays the same compiled program).
        Round-trip through ``repro.serving.export.save_servable``.
        """
        from repro.serving.export import ServableModel
        params = jax.tree.map(np.asarray,
                              self.params_for_eval(state, client_idx))
        m = {"strategy": self.name, "client_idx": int(client_idx),
             "n_clients": self.n_clients, **(meta or {})}
        return ServableModel(adapter=self.adapter, params=params,
                             shared=self.shared_eval_params, meta=m)

    # -- whole-run training ----------------------------------------------------
    @property
    def _whole_run(self) -> bool:
        """Strategy supports lowering a multi-epoch run into ONE program."""
        return False

    def _run_compiled(self, state, client_data, rng, batch_size, n_epochs):
        raise NotImplementedError

    def run(self, state, client_data, rng, batch_size, n_epochs,
            observe=None):
        """Train ``n_epochs`` epochs/rounds; returns ``(state, logs)`` with
        one ``EpochLog`` per epoch.

        Under the compiled engine the WHOLE run lowers into a single XLA
        program — an outer scan over rounds wrapping the epoch body, with
        the FedAvg aggregation / SFLv2 client averaging folded in — so one
        host dispatch executes every epoch.  Strategies that cannot fold
        their round boundary in-graph (secure aggregation's host-side
        masked uploads) and the stepwise engine fall back to a per-epoch
        loop; both orders consume ``rng`` and the PRNG step counter
        identically, so results match the fused path to float round-off.

        ``observe`` (repro.obs.Telemetry | True | False | None) overrides
        the constructor's telemetry spec for this run: the metric taps
        ride the scans as extra outputs — the whole run stays ONE dispatch
        and params are bit-identical to an unobserved run — and the
        reduced per-round telemetry lands on each ``EpochLog.telemetry``
        plus ``self.last_run_telemetry``.  ``None`` inherits the
        constructor setting; ``False`` disables for this run.
        """
        if n_epochs <= 0:
            return state, []
        from repro.obs.telemetry import as_telemetry
        if observe is None:
            tel = self.observe
        else:
            tel = None if observe is False else as_telemetry(observe)
        prev = self._tel_active
        self._tel_active = tel
        try:
            with self._span("run", strategy=self.name, n_epochs=n_epochs):
                if self.engine == "compiled" and self._whole_run:
                    out = self._run_compiled(state, client_data, rng,
                                             batch_size, n_epochs)
                    if out is not None:  # None: degenerate run, fall back
                        state, logs = out
                        return state, self._finish_run(client_data,
                                                       batch_size, logs)
                    if self.participation is not None:
                        # the per-epoch fallback has no slot packing; a
                        # degenerate participating run trains nothing
                        return state, self._finish_run(client_data,
                                                       batch_size, [])
                logs = []
                for i in range(n_epochs):
                    with self._span(f"round {i}"):
                        state, log = self.run_epoch(state, client_data,
                                                    rng, batch_size)
                    logs.append(log)
                return state, self._finish_run(client_data, batch_size,
                                               logs)
        finally:
            self._tel_active = prev

    def _finish_run(self, client_data, batch_size, logs):
        """Assemble ``last_run_telemetry`` (one RoundTelemetry per epoch
        plus the per-round cumulative RDP epsilon series) from the logs an
        observed run produced."""
        tel = self._tel_active
        if tel is None:
            self.last_run_telemetry = None
            return logs
        from repro.obs import telemetry as T
        rounds = []
        for i, log in enumerate(logs):
            r = log.telemetry
            if r is None:
                r = T.RoundTelemetry(i, {})
                log.telemetry = r
            r.round_index = i
            rounds.append(r)
        if tel.epsilon and self._dp:
            ns = [len(d["label"]) for d in client_data]
            kw = {}
            part = self.participation
            if part is not None and part.kind != "schedule":
                # amplification: every hospital composes every round at
                # the amplified rate over its would-be step count (the
                # realized zeros of unsampled rounds don't apply here);
                # deterministic schedules keep the realized client_steps
                kw = dict(q_scale=part.rate,
                          steps_override=getattr(self, "_last_part_nbs",
                                                 None))
            eps = T.epsilon_rounds(self.privacy, logs, ns, batch_size,
                                   pooled=self._eps_pooled, **kw)
            if eps is not None:
                for r, e in zip(rounds, eps):
                    r.epsilon = e
        self.last_run_telemetry = T.RunTelemetry(self.name, self.n_clients,
                                                 rounds)
        return logs

    # -- observability plumbing (repro.obs) -----------------------------------
    @property
    def _tel(self):
        """The active Telemetry spec (run() override or the constructor's)."""
        return self._tel_active

    def attach_tracer(self, tracer):
        """Attach a ``repro.obs.trace.Tracer`` — host-side phases (pack /
        dispatch / collect / rounds) get recorded as spans."""
        self._tracer = tracer
        return tracer

    def _span(self, name, **args):
        if self._tracer is None:
            import contextlib
            return contextlib.nullcontext()
        return self._tracer.span(name, **args)

    def _count_dispatch(self, n: int = 1):
        """Tally one host->device training-program invocation (a compiled
        epoch/run call or a stepwise per-batch step)."""
        self._dispatches += n

    def _get_obs(self, attr, tel, build):
        """Cache an observed (telemetry-variant) compiled program under
        ``attr``, keyed on the Telemetry spec — separate from the
        unobserved caches so enabling telemetry never evicts them."""
        cache = getattr(self, attr, None)
        if cache is None or cache[0] != tel:
            cache = (tel, build())
            setattr(self, attr, cache)
        return cache[1]

    # -- privacy plumbing -----------------------------------------------------
    @property
    def _dp(self) -> bool:
        """DP-SGD (clip/noise on gradients) active."""
        return self.privacy is not None and self.privacy.dp_enabled

    @property
    def _keyed(self) -> bool:
        """Jitted step consumes a PRNG key (DP-SGD or cut-layer noise)."""
        p = self.privacy
        return p is not None and (p.dp_enabled or p.cut_noise_std > 0)

    def _privacy_base_key(self):
        if not hasattr(self, "_base_key"):
            seed = self.privacy.seed if self.privacy is not None else 0
            self._base_key = jax.random.key(seed)
        return self._base_key

    def _next_key(self):
        """Fresh per-step key derived from the privacy seed."""
        from repro.privacy.dpsgd import step_key
        self._key_step += 1
        return step_key(self._privacy_base_key(), np.uint32(self._key_step))

    def _take_key_indices(self, count: int) -> np.ndarray:
        """Reserve ``count`` sequential step-key indices.

        The compiled engine derives step keys INSIDE the scan as
        ``fold_in(base_key, index)`` — reserving the same running counter the
        stepwise path consumes keeps the two paths' noise draws identical.
        """
        start = self._key_step
        self._key_step += count
        return np.arange(start + 1, start + count + 1, dtype=np.uint32)

    def _dp_account(self, client_idx, n_samples, batch_size, count=1,
                    q_scale=1.0):
        """Record ``count`` DP mechanism applications on hospital
        ``client_idx``'s data (sampling rate batch_size / n_samples).

        ``q_scale`` composes per-round client subsampling with the batch
        rate: under ``Participation`` a hospital only contributes a round
        with probability K/N (or q), so each round's mechanisms apply to
        any one example with probability ``q_round * q_batch`` — the
        amplified rate the subsampled-Gaussian RDP bound composes at.
        """
        if not self._dp:
            return
        if self._accountants is None:
            from repro.privacy.accountant import RDPAccountant
            self._accountants = [
                RDPAccountant(self.privacy.noise_multiplier,
                              self.privacy.delta)
                for _ in range(self.n_clients)]
        q = min(batch_size / max(n_samples, 1), 1.0) * q_scale
        self._accountants[client_idx].step(q, count)

    def privacy_report(self) -> list:
        """Per-hospital accountant summaries ((eps, delta) each)."""
        if self._accountants is None:
            return []
        return [a.summary() for a in self._accountants]

    # -- common ---------------------------------------------------------------
    def _scores_all_fn(self, placed: bool = False):
        """Jitted (vmap over hospitals) x (vmap over batches) scorer: ONE
        dispatch evaluates every hospital's padded epoch.  ``placed`` runs
        the hospital vmap inside ``shard_map`` chunks on the "hosp" mesh
        (the SPMD partitioner cannot split vmapped convs, so multi-device
        eval chunks explicitly, like the training engine)."""
        fs = self.adapter.full_scores
        in_p = None if self.shared_eval_params else 0
        vmapped = jax.vmap(lambda p, d: jax.vmap(partial(fs, p))(d),
                           in_axes=(in_p, 0))
        if not placed:
            if not hasattr(self, "_scores_all_jit"):
                self._scores_all_jit = jax.jit(vmapped)
            return self._scores_all_jit
        if not hasattr(self, "_scores_all_place_jit"):
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            self._scores_all_place_jit = jax.jit(shard_map(
                vmapped, mesh=self.placement.mesh,
                in_specs=(P() if self.shared_eval_params else P("hosp"),
                          P("hosp")),
                out_specs=P("hosp"), check_rep=False))
        return self._scores_all_place_jit

    def _stacked_eval_params(self, state):
        if self.shared_eval_params:
            return self.params_for_eval(state, 0)
        return stack_trees([self.params_for_eval(state, i)
                            for i in range(self.n_clients)])

    def _dispatch_scores(self, params, stacked, placed=False,
                         chunk_batches=None, place=None):
        """Run the vmapped scorer over a ``[C, nb, bs, ...]`` data stack,
        optionally chunking the batch axis so an epoch larger than one
        device batch never materializes as a single device buffer.

        Chunks are fixed-shape ``[C, chunk, bs, ...]`` slices (the last
        chunk pads by repeating its final batch slice, scores sliced
        off), so the chunked path compiles ONE extra program total and
        its per-example math is the same vmapped scorer — parity with
        the unchunked dispatch is tested at <=1e-5.  Returns
        ``[C, nb * bs, ...]``.
        """
        fn = self._scores_all_fn(placed)
        nb = next(iter(stacked.values())).shape[1]
        put = (place.put if placed and place is not None
               else (lambda t: t))
        if chunk_batches is None or int(chunk_batches) >= nb:
            out = np.asarray(fn(params, put(stacked)))
            return out.reshape(out.shape[0], -1, *out.shape[3:])
        ch = int(chunk_batches)
        if ch < 1:
            raise ValueError("chunk_batches must be >= 1")
        outs = []
        for s in range(0, nb, ch):
            sl = {k: v[:, s:s + ch] for k, v in stacked.items()}
            m = min(ch, nb - s)
            if m < ch:
                sl = {k: np.concatenate(
                    [v, np.repeat(v[:, -1:], ch - m, axis=1)], axis=1)
                    for k, v in sl.items()}
            o = np.asarray(fn(params, put(sl)))
            outs.append(o[:, :m])
        out = np.concatenate(outs, axis=1)
        return out.reshape(out.shape[0], -1, *out.shape[3:])

    def scores_all(self, state, datas: list, batch_size=60,
                   chunk_batches=None):
        """Per-sample scores for every hospital in a single jitted dispatch.

        Each hospital's split is padded (repeating the last row — the
        existing partial-batch idiom) to a common ``nb * bs`` grid, stacked
        along a leading hospital axis, and scored by the vmapped scorer;
        padding rows are sliced off per hospital.  With placement enabled
        the hospital axis of the data stack (and the stacked params) is
        padded to the mesh multiple and placed on the "hosp" mesh —
        phantom-row scores are computed and discarded.

        ``chunk_batches`` caps how many padded batches one dispatch
        scores: an epoch bigger than one device batch streams through
        fixed-shape ``[C, chunk_batches, bs, ...]`` slices instead of
        materializing the whole grid on device (parity <=1e-5 with the
        unchunked path; ``None`` keeps the single dispatch).
        """
        ns = [len(d["label"]) for d in datas]
        n_max = max(ns, default=0)
        if n_max == 0:
            return [np.zeros((0,)) for _ in datas]
        bs = min(batch_size, n_max)
        nb = -(-n_max // bs)
        L = nb * bs

        def pad(v):
            if len(v) == 0:                      # empty hospital: all padding
                return np.zeros((L, *v.shape[1:]), v.dtype)
            if len(v) == L:
                return v
            return np.concatenate([v, np.repeat(v[-1:], L - len(v), axis=0)])

        stacked = {k: np.stack([pad(d[k]) for d in datas])
                   for k in datas[0]}
        stacked = {k: v.reshape(len(datas), nb, bs, *v.shape[2:])
                   for k, v in stacked.items()}
        params = self._stacked_eval_params(state)
        place = self.placement
        placed = place.enabled and len(datas) == self.n_clients
        if placed:
            stacked = {k: place.pad_rows(v) for k, v in stacked.items()}
            if not self.shared_eval_params:
                params = place.put(place.pad_tree(params))
        out = self._dispatch_scores(params, stacked, placed=placed,
                                    chunk_batches=chunk_batches,
                                    place=place)
        return [out[i, :ns[i]] for i in range(len(datas))]

    def scores(self, state, client_idx, data, batch_size=60,
               chunk_batches=None):
        """Per-sample scores for EVERY sample of one hospital (the final
        partial batch is padded and sliced, so small hospitals never lose
        eval samples).  Routed through the same vmapped scorer as
        ``scores_all`` with a singleton hospital axis; ``chunk_batches``
        streams large datasets through fixed-shape slices exactly as in
        ``scores_all``."""
        n = len(data["label"])
        if n == 0:
            return np.zeros((0,))
        params = self.params_for_eval(state, client_idx)
        if not self.shared_eval_params:
            params = stack_trees([params])
        bs = min(batch_size, n)
        nb = -(-n // bs)
        L = nb * bs
        stacked = {}
        for k, v in data.items():
            if len(v) != L:
                v = np.concatenate([v, np.repeat(v[-1:], L - len(v), axis=0)])
            stacked[k] = v.reshape(1, nb, bs, *v.shape[1:])
        out = self._dispatch_scores(params, stacked,
                                    chunk_batches=chunk_batches)
        return out[0][:n]

    def evaluate(self, state, clients, split="test", batch_size=60):
        """Pooled metrics across clients, each scored by its own front —
        all hospitals evaluated in one dispatch via ``scores_all``."""
        from repro.train import metrics as MET
        datas = [getattr(c, split) for c in clients]
        scores = self.scores_all(state, datas, batch_size)
        all_labels = [d["label"][:len(s)] for d, s in zip(datas, scores)]
        return MET.all_metrics(np.concatenate(all_labels),
                               np.concatenate(scores))

    def val_loss(self, state, clients, batch_size=60):
        if not hasattr(self, "_val_loss_jit"):
            self._val_loss_jit = jax.jit(partial(self.adapter.full_loss,
                                                 train=False))
        fn = self._val_loss_jit
        tot, n = 0.0, 0
        for i, c in enumerate(clients):
            params = self.params_for_eval(state, i)
            for b in np_batches(c.val, min(batch_size, len(c.val["label"])),
                                None):
                tot += float(fn(params, b)); n += 1
        return tot / max(n, 1)


# ---------------------------------------------------------------------------
# pure step functions — shared verbatim by the stepwise jit wrappers below
# and the compiled engine's scan bodies (repro.core.strategies.engine)
# ---------------------------------------------------------------------------

def full_step_fn(adapter: SplitAdapter, opt: O.Optimizer, privacy=None,
                 telemetry=None):
    """Pure step over ALL segments jointly (centralized / FL local).

    Returns ``(step, keyed)`` with
    ``step(params, opt_state, batch, key=None, weights=None)``; ``key`` is
    consumed only when ``keyed`` (DP-SGD), ``weights`` are per-example
    pad-mask weights (None == plain batch mean; unsupported under DP).

    With a ``telemetry`` spec (repro.obs.Telemetry) the step returns one
    extra trailing dict of float32 scalar metric taps (static key set from
    ``telemetry.step_keys``) computed from intermediates the step already
    has — no extra PRNG draws, no reordered math, so params stay
    bit-identical to the unobserved step.
    """
    dp = privacy is not None and privacy.dp_enabled
    if dp:
        from repro.privacy.dpsgd import dp_value_and_grad, keyed

        if telemetry is not None:
            from repro.obs import telemetry as T
            keys = telemetry.step_keys(dp=True, cut=False)
            vg = dp_value_and_grad(keyed(adapter.full_loss), privacy,
                                   with_norms="clip_frac" in keys)

            def dp_step_obs(params, opt_state, batch, key=None,
                            weights=None):
                out = vg(params, batch, key, weights)
                loss, grads = out[0], out[1]
                updates, opt_state = opt.update(grads, opt_state, params)
                met = {}
                if "grad_norm" in keys:
                    met["grad_norm"] = T.global_norm(grads)
                    met["update_norm"] = T.global_norm(updates)
                if "clip_frac" in keys:
                    met["clip_frac"] = T.clip_fraction(
                        out[2]["norms"], privacy.clip_norm, weights)
                return (O.apply_updates(params, updates), opt_state, loss,
                        met)
            return dp_step_obs, True

        vg = dp_value_and_grad(keyed(adapter.full_loss), privacy)

        def dp_step(params, opt_state, batch, key=None, weights=None):
            # weights (0/1 pad mask) ride through the DP estimator itself:
            # zero-weight padded examples clip to zero contribution and the
            # 1/B mean divides by the REAL example count
            loss, grads = vg(params, batch, key, weights)
            updates, opt_state = opt.update(grads, opt_state, params)
            return O.apply_updates(params, updates), opt_state, loss
        return dp_step, True

    if telemetry is not None:
        from repro.obs import telemetry as T
        keys = telemetry.step_keys(dp=False, cut=False)

        def step_obs(params, opt_state, batch, key=None, weights=None):
            loss, grads = jax.value_and_grad(
                lambda p: adapter.full_loss(p, batch,
                                            weights=weights))(params)
            updates, opt_state = opt.update(grads, opt_state, params)
            met = {}
            if "grad_norm" in keys:
                met["grad_norm"] = T.global_norm(grads)
                met["update_norm"] = T.global_norm(updates)
            return O.apply_updates(params, updates), opt_state, loss, met
        return step_obs, False

    def step(params, opt_state, batch, key=None, weights=None):
        loss, grads = jax.value_and_grad(
            lambda p: adapter.full_loss(p, batch, weights=weights))(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return O.apply_updates(params, updates), opt_state, loss
    return step, False


def split_step_fn(adapter: SplitAdapter, opt_client: O.Optimizer,
                  opt_server: O.Optimizer, transport=None, privacy=None,
                  telemetry=None):
    """Pure SL/SFLv2 step: joint grad through client_i(+tail_i) and server.

    Numerically identical to the paper's two-hop backprop; the hop itself is
    the activation/gradient transfer accounted in repro.core.comm.  With a
    ``transport`` (repro.wire), the cut-layer activations are roundtripped
    through its codec in-graph — the server trains on what crossed the wire.

    Returns ``(step, keyed)`` with ``step(client_params, server_params,
    c_opt, s_opt, batch, key=None, weights=None)``.  A privacy config makes
    the step keyed: DP-SGD clips/noises the JOINT (client, server)
    per-example gradient, and/or Gaussian cut-layer noise rides on the
    boundary after the codec.  Cut-layer noise draws are per-example
    (``repro.privacy.dpsgd.cut_noise_boundary``), so a pad-and-mask padded
    remainder batch (``weights``) noises its real rows exactly as the
    stepwise short batch — padded rows get zero noise and zero loss weight.

    With a ``telemetry`` spec the step returns one extra trailing metric
    dict; cut-layer payload stats observe the FIRST boundary crossing
    (front->middle — the cut) exactly as it ships: post-codec, post-noise.
    Observation never draws keys or reorders the update math.
    """
    nls = adapter.nls
    base_boundary = transport.boundary if transport is not None else None
    # fusable codec: roundtrip+cut-noise as one kernel (bit-equal) when the
    # transport allows it — boundary_with_key does the dispatch
    fuse_codec = transport.fused_codec if transport is not None else None
    priv = (privacy if privacy is not None and
            (privacy.dp_enabled or privacy.cut_noise_std > 0) else None)
    if telemetry is not None:
        from repro.obs import telemetry as T
        keys = telemetry.step_keys(
            dp=priv is not None and priv.dp_enabled, cut=True)
        want_cut = "cut_mean" in keys
        want_clip = "clip_frac" in keys
        want_norms = "grad_norm" in keys

    if priv is not None:
        from repro.privacy.dpsgd import boundary_with_key, dp_value_and_grad

        if telemetry is not None:
            def dp_step_obs(client_params, server_params, c_opt, s_opt,
                            batch, key=None, weights=None):
                both0 = {"c": client_params, "s": server_params}
                met = {}
                if priv.dp_enabled:
                    def loss_fn(both, b, k):
                        params = {"front": both["c"]["front"],
                                  "middle": both["s"]}
                        if nls:
                            params["tail"] = both["c"]["tail"]
                        sink = []
                        bnd = boundary_with_key(base_boundary, priv, k,
                                                codec=fuse_codec)
                        if want_cut:
                            bnd = T.observing_boundary(bnd, sink)
                        loss = adapter.full_loss(params, b, boundary=bnd)
                        if want_cut:
                            return loss, T.payload_moments(sink[0])
                        return loss

                    out = dp_value_and_grad(
                        loss_fn, priv, has_aux=want_cut,
                        with_norms=want_clip)(both0, batch, key, weights)
                    loss, g = out[0], out[1]
                    extras = out[2] if (want_cut or want_clip) else {}
                    if want_cut:
                        met.update(T.moments_to_stats(
                            *T.combine_moments(*extras["aux"], weights)))
                    if want_clip:
                        met["clip_frac"] = T.clip_fraction(
                            extras["norms"], priv.clip_norm, weights)
                else:
                    def loss_fn(both, b, k):
                        params = {"front": both["c"]["front"],
                                  "middle": both["s"]}
                        if nls:
                            params["tail"] = both["c"]["tail"]
                        sink = []
                        bnd = boundary_with_key(base_boundary, priv, k,
                                                weights, codec=fuse_codec)
                        if want_cut:
                            bnd = T.observing_boundary(bnd, sink)
                        loss = adapter.full_loss(params, b, boundary=bnd,
                                                 weights=weights)
                        if want_cut:
                            return loss, T.payload_moments(sink[0],
                                                           weights)
                        return loss

                    if want_cut:
                        (loss, mom), g = jax.value_and_grad(
                            loss_fn, has_aux=True)(both0, batch, key)
                        met.update(T.moments_to_stats(*mom))
                    else:
                        loss, g = jax.value_and_grad(loss_fn)(both0, batch,
                                                              key)
                cu, c_opt = opt_client.update(g["c"], c_opt, client_params)
                su, s_opt = opt_server.update(g["s"], s_opt, server_params)
                if want_norms:
                    met["grad_norm"] = T.global_norm(g)
                    met["update_norm"] = T.global_norm((cu, su))
                return (O.apply_updates(client_params, cu),
                        O.apply_updates(server_params, su), c_opt, s_opt,
                        loss, met)
            return dp_step_obs, True

        def dp_step(client_params, server_params, c_opt, s_opt, batch,
                    key=None, weights=None):
            def loss_fn(both, b, k):
                params = {"front": both["c"]["front"], "middle": both["s"]}
                if nls:
                    params["tail"] = both["c"]["tail"]
                # under DP the estimator itself weights the clipped
                # per-example grads, so the inner loss stays per-example
                return adapter.full_loss(
                    params, b,
                    boundary=boundary_with_key(
                        base_boundary, priv, k,
                        None if priv.dp_enabled else weights,
                        codec=fuse_codec),
                    weights=None if priv.dp_enabled else weights)

            if priv.dp_enabled:
                loss, g = dp_value_and_grad(loss_fn, priv)(
                    {"c": client_params, "s": server_params}, batch, key,
                    weights)
            else:
                loss, g = jax.value_and_grad(loss_fn)(
                    {"c": client_params, "s": server_params}, batch, key)
            cu, c_opt = opt_client.update(g["c"], c_opt, client_params)
            su, s_opt = opt_server.update(g["s"], s_opt, server_params)
            return (O.apply_updates(client_params, cu),
                    O.apply_updates(server_params, su), c_opt, s_opt, loss)
        return dp_step, True

    if telemetry is not None:
        def step_obs(client_params, server_params, c_opt, s_opt, batch,
                     key=None, weights=None):
            def loss_fn(cp, sp):
                params = {"front": cp["front"], "middle": sp}
                if nls:
                    params["tail"] = cp["tail"]
                sink = []
                bnd = (T.observing_boundary(base_boundary, sink)
                       if want_cut else base_boundary)
                loss = adapter.full_loss(params, batch, boundary=bnd,
                                         weights=weights)
                if want_cut:
                    return loss, T.payload_moments(sink[0], weights)
                return loss

            if want_cut:
                (loss, mom), (gc, gs) = jax.value_and_grad(
                    loss_fn, argnums=(0, 1), has_aux=True)(client_params,
                                                           server_params)
            else:
                loss, (gc, gs) = jax.value_and_grad(
                    loss_fn, argnums=(0, 1))(client_params, server_params)
            cu, c_opt = opt_client.update(gc, c_opt, client_params)
            su, s_opt = opt_server.update(gs, s_opt, server_params)
            met = {}
            if want_cut:
                met.update(T.moments_to_stats(*mom))
            if want_norms:
                met["grad_norm"] = T.global_norm((gc, gs))
                met["update_norm"] = T.global_norm((cu, su))
            return (O.apply_updates(client_params, cu),
                    O.apply_updates(server_params, su), c_opt, s_opt, loss,
                    met)
        return step_obs, False

    def step(client_params, server_params, c_opt, s_opt, batch, key=None,
             weights=None):
        def loss_fn(cp, sp):
            params = {"front": cp["front"], "middle": sp}
            if nls:
                params["tail"] = cp["tail"]
            return adapter.full_loss(params, batch, boundary=base_boundary,
                                     weights=weights)

        loss, (gc, gs) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
            client_params, server_params)
        cu, c_opt = opt_client.update(gc, c_opt, client_params)
        su, s_opt = opt_server.update(gs, s_opt, server_params)
        return (O.apply_updates(client_params, cu),
                O.apply_updates(server_params, su), c_opt, s_opt, loss)
    return step, False


def sflv3_step_fn(adapter: SplitAdapter, opt_client: O.Optimizer,
                  opt_server: O.Optimizer, n_clients: int, transport=None,
                  privacy=None, client_weights=None, mesh_axis=None,
                  telemetry=None):
    """Pure SplitFedv3 step (paper Algorithm 1, batch-synchronous form):
    clients run in parallel (vmap over the stacked client axis); the server
    segment is updated once with the weighted average of per-client server
    gradients; client segments update individually (never averaged).

    ``n_clients`` is the LOCAL stacked row count the step vmaps over.
    ``client_weights`` (a GLOBAL 0/1 mask over all rows, e.g.
    ``Placement.client_weights``) excludes phantom padding rows from the
    server average and the reported loss — with weights of all ones (or
    None) the math is EXACTLY the unweighted step.  Under ``mesh_axis``
    (the compiled engine's ``shard_map`` over the "hosp" mesh) each device
    holds ``n_clients`` of the global rows: per-client keys and weights
    index by the GLOBAL row (``axis_index * n_clients + local``), and the
    server-gradient average is completed with a ``psum`` — so the update
    is bit-comparable to the single-device step regardless of the chunking.

    Returns ``(step, keyed)`` with ``step(stacked_clients, server_params,
    c_opt, s_opt, stacked_batch, key=None)``.  A privacy config makes the
    step keyed: every client clips and noises its OWN per-example gradients
    (per-client keys are ``fold_in(step_key, global_client_idx)``, so a
    real hospital's draws do not depend on how many padding rows ride
    along) before the server averages, so each hospital's DP guarantee
    stands on its own.

    With a ``telemetry`` spec the step returns one extra trailing metric
    dict of per-client ``[n_clients]`` float32 taps (cut-layer payload
    stats, joint client+server grad/update norms, DP clip fractions) —
    pure observation of intermediates, params stay bit-identical.
    """
    import jax.numpy as jnp
    nls = adapter.nls
    boundary = transport.boundary if transport is not None else None
    fuse_codec = transport.fused_codec if transport is not None else None
    priv = (privacy if privacy is not None and
            (privacy.dp_enabled or privacy.cut_noise_std > 0) else None)
    if telemetry is not None:
        from repro.obs import telemetry as T
        tel_keys = telemetry.step_keys(
            dp=priv is not None and priv.dp_enabled, cut=True)
        want_cut = "cut_mean" in tel_keys
        want_clip = "clip_frac" in tel_keys
        want_norms = "grad_norm" in tel_keys
    w_global = (np.ones((n_clients,), np.float32)
                if client_weights is None
                else np.asarray(client_weights, np.float32))
    w_sum = float(w_global.sum())

    def _local_rows():
        """(row offset, local weight column) for this device's chunk."""
        off = (0 if mesh_axis is None
               else jax.lax.axis_index(mesh_axis) * n_clients)
        w = jax.lax.dynamic_slice(jnp.asarray(w_global), (off,),
                                  (n_clients,))
        return off, w

    def _server_mean(gs_local):
        if mesh_axis is None:
            return gs_local
        return jax.lax.psum(gs_local, mesh_axis)

    if priv is not None:
        from repro.privacy.dpsgd import boundary_with_key, dp_value_and_grad

        if telemetry is not None:
            def dp_step_obs(stacked_clients, server_params, c_opt, s_opt,
                            stacked_batch, key=None, gids=None):
                off, w_local = _local_rows()
                # under participation each slot keys by its GLOBAL hospital
                # id, so a hospital's DP draws are co-sample independent
                rows = (gids.astype(jnp.uint32) if gids is not None
                        else (off + jnp.arange(n_clients)).astype(jnp.uint32))
                keys = jax.vmap(lambda c: jax.random.fold_in(key, c))(rows)

                def loss_fn(both, b, k):
                    params = {"front": both["c"]["front"],
                              "middle": both["s"]}
                    if nls:
                        params["tail"] = both["c"]["tail"]
                    sink = []
                    bnd = boundary_with_key(boundary, priv, k,
                                            codec=fuse_codec)
                    if want_cut:
                        bnd = T.observing_boundary(bnd, sink)
                    loss = adapter.full_loss(params, b, boundary=bnd)
                    if want_cut:
                        return loss, T.payload_moments(sink[0])
                    return loss

                if priv.dp_enabled:
                    vg = dp_value_and_grad(loss_fn, priv, has_aux=want_cut,
                                           with_norms=want_clip)
                else:
                    vg = jax.value_and_grad(loss_fn, has_aux=want_cut)

                def one(cp, b, k):
                    met_c = {}
                    if priv.dp_enabled:
                        out = vg({"c": cp, "s": server_params}, b, k)
                        loss, g = out[0], out[1]
                        if want_cut:
                            met_c.update(T.moments_to_stats(
                                *T.combine_moments(*out[2]["aux"])))
                        if want_clip:
                            met_c["clip_frac"] = T.clip_fraction(
                                out[2]["norms"], priv.clip_norm)
                    elif want_cut:
                        (loss, mom), g = vg({"c": cp, "s": server_params},
                                            b, k)
                        met_c.update(T.moments_to_stats(*mom))
                    else:
                        loss, g = vg({"c": cp, "s": server_params}, b, k)
                    if want_norms:
                        met_c["grad_norm"] = T.global_norm(g)
                    return loss, g, met_c

                losses, g, met = jax.vmap(one)(stacked_clients,
                                               stacked_batch, keys)
                gc = g["c"]                      # already per-client grads
                gs = _server_mean(jax.tree.map(
                    lambda x: (x * w_local.reshape(
                        (-1,) + (1,) * (x.ndim - 1))).sum(axis=0) / w_sum,
                    g["s"]))
                cu, c_opt = opt_client.update(gc, c_opt, stacked_clients)
                su, s_opt = opt_server.update(gs, s_opt, server_params)
                if want_norms:
                    met["update_norm"] = jnp.sqrt(
                        jax.vmap(lambda u: jnp.square(T.global_norm(u)))(cu)
                        + jnp.square(T.global_norm(su)))
                return (O.apply_updates(stacked_clients, cu),
                        O.apply_updates(server_params, su), c_opt, s_opt,
                        losses, met)
            return dp_step_obs, True

        def dp_step(stacked_clients, server_params, c_opt, s_opt,
                    stacked_batch, key=None, gids=None):
            off, w_local = _local_rows()
            rows = (gids.astype(jnp.uint32) if gids is not None
                    else (off + jnp.arange(n_clients)).astype(jnp.uint32))
            keys = jax.vmap(lambda c: jax.random.fold_in(key, c))(rows)

            def loss_fn(both, b, k):
                params = {"front": both["c"]["front"], "middle": both["s"]}
                if nls:
                    params["tail"] = both["c"]["tail"]
                return adapter.full_loss(
                    params, b, boundary=boundary_with_key(boundary, priv, k,
                                                          codec=fuse_codec))

            vg = (dp_value_and_grad(loss_fn, priv) if priv.dp_enabled
                  else jax.value_and_grad(loss_fn))

            def one(cp, b, k):
                return vg({"c": cp, "s": server_params}, b, k)

            losses, g = jax.vmap(one)(stacked_clients, stacked_batch, keys)
            gc = g["c"]                          # already per-client grads
            gs = _server_mean(jax.tree.map(
                lambda x: (x * w_local.reshape((-1,) + (1,) * (x.ndim - 1))
                           ).sum(axis=0) / w_sum, g["s"]))
            cu, c_opt = opt_client.update(gc, c_opt, stacked_clients)
            su, s_opt = opt_server.update(gs, s_opt, server_params)
            return (O.apply_updates(stacked_clients, cu),
                    O.apply_updates(server_params, su), c_opt, s_opt,
                    losses)
        return dp_step, True

    if telemetry is not None:
        def step_obs(stacked_clients, server_params, c_opt, s_opt,
                     stacked_batch, key=None, gids=None):
            del gids  # keyless step: slot identity only affects PRNG rows
            _, w_local = _local_rows()

            def client_loss(cp, sp, batch):
                params = {"front": cp["front"], "middle": sp}
                if nls:
                    params["tail"] = cp["tail"]
                sink = []
                bnd = (T.observing_boundary(boundary, sink) if want_cut
                       else boundary)
                loss = adapter.full_loss(params, batch, boundary=bnd)
                return loss, (T.payload_moments(sink[0]) if want_cut
                              else ())

            def mean_loss(sc, sp):
                losses, moms = jax.vmap(
                    lambda cp, b: client_loss(cp, sp, b))(sc, stacked_batch)
                return (losses * w_local).sum() / w_sum, (losses, moms)

            (_, (losses, moms)), (gc, gs) = jax.value_and_grad(
                mean_loss, argnums=(0, 1), has_aux=True)(stacked_clients,
                                                         server_params)
            gc = jax.tree.map(lambda g: g * w_sum, gc)
            gs = _server_mean(gs)
            cu, c_opt = opt_client.update(gc, c_opt, stacked_clients)
            su, s_opt = opt_server.update(gs, s_opt, server_params)
            met = {}
            if want_cut:
                met.update(T.moments_to_stats(*moms))
            if want_norms:
                # per-client joint norm: own segment grad + the (shared)
                # mean server grad — the update each hospital experiences
                met["grad_norm"] = jnp.sqrt(
                    jax.vmap(lambda g_: jnp.square(T.global_norm(g_)))(gc)
                    + jnp.square(T.global_norm(gs)))
                met["update_norm"] = jnp.sqrt(
                    jax.vmap(lambda u: jnp.square(T.global_norm(u)))(cu)
                    + jnp.square(T.global_norm(su)))
            return (O.apply_updates(stacked_clients, cu),
                    O.apply_updates(server_params, su), c_opt, s_opt,
                    losses, met)
        return step_obs, False

    def step(stacked_clients, server_params, c_opt, s_opt, stacked_batch,
             key=None, gids=None):
        del gids  # keyless step: slot identity only affects PRNG rows
        _, w_local = _local_rows()

        def client_loss(cp, sp, batch):
            params = {"front": cp["front"], "middle": sp}
            if nls:
                params["tail"] = cp["tail"]
            return adapter.full_loss(params, batch, boundary=boundary)

        def mean_loss(sc, sp):
            losses = jax.vmap(lambda cp, b: client_loss(cp, sp, b))(
                sc, stacked_batch)
            return (losses * w_local).sum() / w_sum, losses

        (loss, losses), (gc, gs) = jax.value_and_grad(
            mean_loss, argnums=(0, 1), has_aux=True)(stacked_clients,
                                                     server_params)
        # gc is stacked per-client (weighted-mean grad => scale back to
        # per-client; a zero-weight phantom row's grad is exactly zero, so
        # the uniform w_sum rescale leaves it zero)
        gc = jax.tree.map(lambda g: g * w_sum, gc)
        gs = _server_mean(gs)
        cu, c_opt = opt_client.update(gc, c_opt, stacked_clients)
        su, s_opt = opt_server.update(gs, s_opt, server_params)
        return (O.apply_updates(stacked_clients, cu),
                O.apply_updates(server_params, su), c_opt, s_opt, losses)
    return step, False


# ---------------------------------------------------------------------------
# jitted step builders — the stepwise engine's per-batch dispatch wrappers
# ---------------------------------------------------------------------------

def make_full_step(adapter: SplitAdapter, opt: O.Optimizer, privacy=None,
                   telemetry=None):
    """Jitted plain step (centralized / FL local); see ``full_step_fn``.
    With DP the returned step takes a fourth ``key`` argument; with
    ``telemetry`` it returns a trailing metric dict."""
    step, keyed_ = full_step_fn(adapter, opt, privacy, telemetry)
    if keyed_:
        return jax.jit(lambda p, s, b, k: step(p, s, b, k))
    return jax.jit(lambda p, s, b: step(p, s, b))


def make_split_step(adapter: SplitAdapter, opt_client: O.Optimizer,
                    opt_server: O.Optimizer, transport=None, privacy=None,
                    telemetry=None):
    """Jitted SL/SFLv2 step; see ``split_step_fn``.  A privacy config adds
    a sixth ``key`` argument; ``telemetry`` adds a trailing metric dict."""
    step, keyed_ = split_step_fn(adapter, opt_client, opt_server, transport,
                                 privacy, telemetry)
    if keyed_:
        return jax.jit(lambda cp, sp, co, so, b, k: step(cp, sp, co, so, b,
                                                         k))
    return jax.jit(lambda cp, sp, co, so, b: step(cp, sp, co, so, b))


def make_sflv3_step(adapter: SplitAdapter, opt_client: O.Optimizer,
                    opt_server: O.Optimizer, n_clients: int, transport=None,
                    privacy=None, telemetry=None):
    """Jitted SplitFedv3 step; see ``sflv3_step_fn``.  A privacy config
    adds a sixth ``key`` argument; ``telemetry`` adds a trailing metric
    dict."""
    step, keyed_ = sflv3_step_fn(adapter, opt_client, opt_server, n_clients,
                                 transport, privacy, telemetry=telemetry)
    if keyed_:
        return jax.jit(lambda sc, sp, co, so, b, k: step(sc, sp, co, so, b,
                                                         k))
    return jax.jit(lambda sc, sp, co, so, b: step(sc, sp, co, so, b))


__all__ = ["Strategy", "EpochLog", "np_batches", "tree_mean",
           "tree_weighted_mean", "stack_trees", "unstack_tree",
           "full_step_fn", "split_step_fn", "sflv3_step_fn",
           "make_full_step", "make_split_step", "make_sflv3_step"]
