"""Strategy API + shared jitted step builders.

Every strategy consumes a ``SplitAdapter`` (architecture-agnostic) and an
optimizer factory, and exposes:

    setup(key)                        -> state
    run_epoch(state, client_data, rng, batch_size) -> (state, log)
    scores(state, client_idx, data, batch_size)    -> per-sample scores

``client_data`` is a list (len n_clients) of dicts of numpy arrays.
Evaluation follows the paper (§3.4): a sample from hospital i always passes
through hospital i's own client segment(s); FL/centralized have one model.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import SplitAdapter
from repro import optim as O


@dataclasses.dataclass
class EpochLog:
    losses: list
    steps: int

    @property
    def mean_loss(self):
        return float(np.mean(self.losses)) if self.losses else float("nan")


def tree_mean(trees):
    return jax.tree.map(lambda *xs: sum(xs) / len(xs), *trees)


def tree_weighted_mean(trees, weights):
    total = sum(weights)
    return jax.tree.map(
        lambda *xs: sum(w * x for w, x in zip(weights, xs)) / total, *trees)


def np_batches(data: dict, batch_size: int, rng: np.random.Generator | None):
    n = len(next(iter(data.values())))
    idx = np.arange(n)
    if rng is not None:
        rng.shuffle(idx)
    nb = n // batch_size
    return [{k: v[idx[i * batch_size:(i + 1) * batch_size]]
             for k, v in data.items()} for i in range(nb)]


class Strategy:
    name: str = "base"

    def __init__(self, adapter: SplitAdapter, opt_factory: Callable[[], O.Optimizer],
                 n_clients: int, privacy=None):
        self.adapter = adapter
        self.opt_factory = opt_factory
        self.n_clients = n_clients
        self.privacy = privacy          # repro.privacy.PrivacyConfig | None
        self._accountants = None
        self._key_step = 0

    # -- to implement ---------------------------------------------------------
    def setup(self, key):
        raise NotImplementedError

    def run_epoch(self, state, client_data, rng, batch_size):
        raise NotImplementedError

    def params_for_eval(self, state, client_idx) -> dict:
        """Full param dict (all segments) used to score client ``client_idx``."""
        raise NotImplementedError

    # -- privacy plumbing -----------------------------------------------------
    @property
    def _dp(self) -> bool:
        """DP-SGD (clip/noise on gradients) active."""
        return self.privacy is not None and self.privacy.dp_enabled

    @property
    def _keyed(self) -> bool:
        """Jitted step consumes a PRNG key (DP-SGD or cut-layer noise)."""
        p = self.privacy
        return p is not None and (p.dp_enabled or p.cut_noise_std > 0)

    def _next_key(self):
        """Fresh per-step key derived from the privacy seed."""
        if not hasattr(self, "_base_key"):
            seed = self.privacy.seed if self.privacy is not None else 0
            self._base_key = jax.random.key(seed)
        self._key_step += 1
        return jax.random.fold_in(self._base_key, self._key_step)

    def _dp_account(self, client_idx, n_samples, batch_size, count=1):
        """Record ``count`` DP mechanism applications on hospital
        ``client_idx``'s data (sampling rate batch_size / n_samples)."""
        if not self._dp:
            return
        if self._accountants is None:
            from repro.privacy.accountant import RDPAccountant
            self._accountants = [
                RDPAccountant(self.privacy.noise_multiplier,
                              self.privacy.delta)
                for _ in range(self.n_clients)]
        q = min(batch_size / max(n_samples, 1), 1.0)
        self._accountants[client_idx].step(q, count)

    def privacy_report(self) -> list:
        """Per-hospital accountant summaries ((eps, delta) each)."""
        if self._accountants is None:
            return []
        return [a.summary() for a in self._accountants]

    # -- common ---------------------------------------------------------------
    def _scores_fn(self):
        if not hasattr(self, "_scores_jit"):
            self._scores_jit = jax.jit(self.adapter.full_scores)
        return self._scores_jit

    def scores(self, state, client_idx, data, batch_size=60):
        """Per-sample scores for EVERY sample: the final partial batch is
        padded (by repeating the last row) to the jitted batch shape and the
        padding sliced off, so small hospitals never lose eval samples."""
        params = self.params_for_eval(state, client_idx)
        fn = self._scores_fn()
        n = len(data["label"])
        if n == 0:
            return np.zeros((0,))
        bs = min(batch_size, n)
        outs = []
        for start in range(0, n, bs):
            b = {k: v[start:start + bs] for k, v in data.items()}
            m = len(b["label"])
            if m < bs:                     # pad-and-mask the remainder batch
                b = {k: np.concatenate(
                    [v, np.repeat(v[-1:], bs - m, axis=0)]) for k, v in
                    b.items()}
            outs.append(np.asarray(fn(params, b))[:m])
        return np.concatenate(outs) if outs else np.zeros((0,))

    def evaluate(self, state, clients, split="test", batch_size=60):
        """Pooled metrics across clients, each scored by its own front."""
        from repro.train import metrics as MET
        all_scores, all_labels = [], []
        for i, c in enumerate(clients):
            data = getattr(c, split)
            s = self.scores(state, i, data, batch_size)
            all_scores.append(s)
            all_labels.append(data["label"][:len(s)])
        return MET.all_metrics(np.concatenate(all_labels),
                               np.concatenate(all_scores))

    def val_loss(self, state, clients, batch_size=60):
        if not hasattr(self, "_val_loss_jit"):
            self._val_loss_jit = jax.jit(partial(self.adapter.full_loss,
                                                 train=False))
        fn = self._val_loss_jit
        tot, n = 0.0, 0
        for i, c in enumerate(clients):
            params = self.params_for_eval(state, i)
            for b in np_batches(c.val, min(batch_size, len(c.val["label"])),
                                None):
                tot += float(fn(params, b)); n += 1
        return tot / max(n, 1)


# ---------------------------------------------------------------------------
# jitted step builders
# ---------------------------------------------------------------------------

def make_full_step(adapter: SplitAdapter, opt: O.Optimizer, privacy=None):
    """Plain step over ALL segments jointly (centralized / FL local).

    With a DP-enabled ``privacy`` config the returned step takes a fourth
    ``key`` argument and uses the DP-SGD estimator (per-example clip via the
    fused Pallas kernel + Gaussian noise) in place of the batch gradient.
    """
    if privacy is not None and privacy.dp_enabled:
        from repro.privacy.dpsgd import dp_value_and_grad, keyed
        vg = dp_value_and_grad(keyed(adapter.full_loss), privacy)

        @jax.jit
        def dp_step(params, opt_state, batch, key):
            loss, grads = vg(params, batch, key)
            updates, opt_state = opt.update(grads, opt_state, params)
            return O.apply_updates(params, updates), opt_state, loss
        return dp_step

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(adapter.full_loss)(params, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        return O.apply_updates(params, updates), opt_state, loss
    return step


def make_split_step(adapter: SplitAdapter, opt_client: O.Optimizer,
                    opt_server: O.Optimizer, transport=None, privacy=None):
    """One SL/SFLv2 step: joint grad through client_i(+tail_i) and server.

    Numerically identical to the paper's two-hop backprop; the hop itself is
    the activation/gradient transfer accounted in repro.core.comm.  With a
    ``transport`` (repro.wire), the cut-layer activations are roundtripped
    through its codec in-graph — the server trains on what crossed the wire.

    A privacy config adds a sixth ``key`` argument: DP-SGD clips/noises the
    JOINT (client, server) per-example gradient, and/or Gaussian cut-layer
    noise rides on the boundary after the codec.
    """
    nls = adapter.nls
    base_boundary = transport.boundary if transport is not None else None
    priv = (privacy if privacy is not None and
            (privacy.dp_enabled or privacy.cut_noise_std > 0) else None)

    if priv is not None:
        from repro.privacy.dpsgd import boundary_with_key, dp_value_and_grad

        def loss_fn(both, b, k):
            params = {"front": both["c"]["front"], "middle": both["s"]}
            if nls:
                params["tail"] = both["c"]["tail"]
            return adapter.full_loss(
                params, b, boundary=boundary_with_key(base_boundary, priv, k))

        vg = (dp_value_and_grad(loss_fn, priv) if priv.dp_enabled
              else jax.value_and_grad(loss_fn))

        @jax.jit
        def dp_step(client_params, server_params, c_opt, s_opt, batch, key):
            loss, g = vg({"c": client_params, "s": server_params}, batch,
                         key)
            cu, c_opt = opt_client.update(g["c"], c_opt, client_params)
            su, s_opt = opt_server.update(g["s"], s_opt, server_params)
            return (O.apply_updates(client_params, cu),
                    O.apply_updates(server_params, su), c_opt, s_opt, loss)
        return dp_step

    @jax.jit
    def step(client_params, server_params, c_opt, s_opt, batch):
        def loss_fn(cp, sp):
            params = {"front": cp["front"], "middle": sp}
            if nls:
                params["tail"] = cp["tail"]
            return adapter.full_loss(params, batch, boundary=base_boundary)

        loss, (gc, gs) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
            client_params, server_params)
        cu, c_opt = opt_client.update(gc, c_opt, client_params)
        su, s_opt = opt_server.update(gs, s_opt, server_params)
        return (O.apply_updates(client_params, cu),
                O.apply_updates(server_params, su), c_opt, s_opt, loss)
    return step


def make_sflv3_step(adapter: SplitAdapter, opt_client: O.Optimizer,
                    opt_server: O.Optimizer, n_clients: int, transport=None,
                    privacy=None):
    """SplitFedv3 step (paper Algorithm 1, batch-synchronous form):
    clients run in parallel (vmap over the stacked client axis); the server
    segment is updated once with the weighted average of per-client server
    gradients; client segments update individually (never averaged).

    A privacy config adds a sixth ``key`` argument: every client clips and
    noises its OWN per-example gradients (keys split per client) before the
    server averages, so each hospital's DP guarantee stands on its own.
    """
    nls = adapter.nls
    boundary = transport.boundary if transport is not None else None
    priv = (privacy if privacy is not None and
            (privacy.dp_enabled or privacy.cut_noise_std > 0) else None)

    if priv is not None:
        from repro.privacy.dpsgd import boundary_with_key, dp_value_and_grad

        def loss_fn(both, b, k):
            params = {"front": both["c"]["front"], "middle": both["s"]}
            if nls:
                params["tail"] = both["c"]["tail"]
            return adapter.full_loss(
                params, b, boundary=boundary_with_key(boundary, priv, k))

        vg = (dp_value_and_grad(loss_fn, priv) if priv.dp_enabled
              else jax.value_and_grad(loss_fn))

        @jax.jit
        def dp_step(stacked_clients, server_params, c_opt, s_opt,
                    stacked_batch, key):
            keys = jax.random.split(key, n_clients)

            def one(cp, b, k):
                return vg({"c": cp, "s": server_params}, b, k)

            losses, g = jax.vmap(one)(stacked_clients, stacked_batch, keys)
            gc = g["c"]                          # already per-client grads
            gs = jax.tree.map(lambda x: x.mean(axis=0), g["s"])
            cu, c_opt = opt_client.update(gc, c_opt, stacked_clients)
            su, s_opt = opt_server.update(gs, s_opt, server_params)
            return (O.apply_updates(stacked_clients, cu),
                    O.apply_updates(server_params, su), c_opt, s_opt,
                    losses)
        return dp_step

    @jax.jit
    def step(stacked_clients, server_params, c_opt, s_opt, stacked_batch):
        def client_loss(cp, sp, batch):
            params = {"front": cp["front"], "middle": sp}
            if nls:
                params["tail"] = cp["tail"]
            return adapter.full_loss(params, batch, boundary=boundary)

        def mean_loss(sc, sp):
            losses = jax.vmap(lambda cp, b: client_loss(cp, sp, b))(
                sc, stacked_batch)
            return losses.mean(), losses

        (loss, losses), (gc, gs) = jax.value_and_grad(
            mean_loss, argnums=(0, 1), has_aux=True)(stacked_clients,
                                                     server_params)
        # gc is stacked per-client (mean grad => scale back to per-client)
        gc = jax.tree.map(lambda g: g * n_clients, gc)
        cu, c_opt = opt_client.update(gc, c_opt, stacked_clients)
        su, s_opt = opt_server.update(gs, s_opt, server_params)
        return (O.apply_updates(stacked_clients, cu),
                O.apply_updates(server_params, su), c_opt, s_opt, losses)
    return step


def stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def unstack_tree(tree, n):
    return [jax.tree.map(lambda x: x[i], tree) for i in range(n)]
