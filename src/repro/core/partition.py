"""Cut-layer model partitioning — the structural heart of SL / SplitFed.

Both model families (transformer LMs, unit-list CNNs) are wrapped into a
uniform ``SplitAdapter`` so every strategy in ``repro.core.strategies`` is
architecture-agnostic.  Segments:

  * ``front``  — at the client; raw inputs never leave it.
  * ``middle`` — at the server (the bulk of the compute).
  * ``tail``   — at the client again, only in the non-label-sharing
    (U-shaped) configuration; holds the head so labels never leave either.

Activations crossing segment boundaries may be arbitrary pytrees (the U-Net
front emits (hidden, skips)); communication accounting sums leaf bytes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SplitAdapter:
    """Uniform three-segment view of a model for the distributed strategies."""
    name: str
    seg_names: tuple[str, ...]                 # ("front","middle"[,"tail"])
    init: Callable[[Any], Any]                 # key -> params {seg: tree}
    inputs: Callable[[dict], Any]              # batch -> x0
    apply_seg: Callable[..., Any]              # (seg, seg_params, x, batch, train) -> x
    loss_from_output: Callable[[Any, dict], Any]
    scores_from_output: Callable[[Any], Any]   # output -> probabilities
    per_example_loss: Callable[[Any, dict], Any] | None = None  # -> (B,)

    @property
    def nls(self) -> bool:
        return "tail" in self.seg_names

    # -- composition helpers -------------------------------------------------
    def full_loss(self, params, batch, train=True, boundary=None,
                  weights=None):
        """``boundary``: optional fn applied to every cross-segment
        activation pytree (the repro.wire transport hook — the server sees
        what actually crossed the wire).  ``weights``: optional (B,)
        per-example weights — the loss becomes a weighted mean over the
        per-example losses, which is how the compiled engine masks padding
        rows out of a pad-and-mask remainder batch."""
        x = self.inputs(batch)
        last = len(self.seg_names) - 1
        for i, seg in enumerate(self.seg_names):
            x = self.apply_seg(seg, params[seg], x, batch, train)
            if boundary is not None and i < last:
                x = boundary(x)
        if weights is None:
            return self.loss_from_output(x, batch)
        if self.per_example_loss is None:
            raise ValueError(
                f"adapter {self.name!r} has no per_example_loss; weighted "
                "(pad-and-mask) losses need one")
        pe = self.per_example_loss(x, batch).astype(jnp.float32)
        w = weights.astype(jnp.float32)
        return (pe * w).sum() / jnp.maximum(w.sum(), 1.0)

    def full_scores(self, params, batch):
        x = self.inputs(batch)
        for seg in self.seg_names:
            x = self.apply_seg(seg, params[seg], x, batch, False)
        return self.scores_from_output(x)

    # -- boundary shape accounting (for repro.core.comm) ---------------------
    def boundary_specs(self, example_batch: dict, params=None) -> dict:
        """ShapeDtypeStructs of every segment-boundary activation."""
        if params is None:
            params = jax.eval_shape(self.init, jax.random.key(0))

        def front(p, b):
            return self.apply_seg("front", p, self.inputs(b), b, True)

        specs = {}
        h = jax.eval_shape(front, params["front"], example_batch)
        specs["front->middle"] = h
        if self.nls:
            def middle(p, hh, b):
                return self.apply_seg("middle", p, hh, b, True)
            h2 = jax.eval_shape(middle, params["middle"], h, example_batch)
            specs["middle->tail"] = h2
        return specs


# ---------------------------------------------------------------------------
# adapters
# ---------------------------------------------------------------------------

def cnn_adapter(model) -> SplitAdapter:
    """Wrap a repro.models.cnn.CNNModel."""

    def init(key):
        return model.init_params(key)

    def inputs(batch):
        return batch["image"]

    def apply_seg(seg, seg_params, x, batch, train=False):
        return model.apply_segment(seg_params, seg, x, train)

    def loss_from_output(out, batch):
        from repro.models.cnn import bce_loss
        return bce_loss(out, batch["label"])

    def scores_from_output(out):
        return jax.nn.sigmoid(out.reshape(-1).astype(jnp.float32))

    def per_example_loss(out, batch):
        logits = out.reshape(-1).astype(jnp.float32)
        labels = batch["label"].reshape(-1).astype(jnp.float32)
        return (jnp.maximum(logits, 0) - logits * labels
                + jnp.log1p(jnp.exp(-jnp.abs(logits))))

    return SplitAdapter(model.name, tuple(model.seg_names), init, inputs,
                        apply_seg, loss_from_output, scores_from_output,
                        per_example_loss)


def lm_adapter(model) -> SplitAdapter:
    """Wrap a repro.models.transformer.TransformerLM (built with cut/nls)."""
    seg_names = tuple(s.name for s in model.segments)
    seg_index = {s.name: i for i, s in enumerate(model.segments)}

    def init(key):
        return model.init_params(key)

    def inputs(batch):
        return batch["tokens"][:, :-1]

    def apply_seg(seg, seg_params, x, batch, train=False):
        i = seg_index[seg]
        out, _, aux = model.apply({seg: seg_params}, x,
                                  positions=_positions(batch, model),
                                  frontend_emb=batch.get("frontend_emb"),
                                  train=train, segment_range=(i, i + 1))
        # carry aux loss along with activations so it reaches the loss
        if seg == seg_names[-1]:
            return out
        return out

    def _positions(batch, model):
        b, s = batch["tokens"].shape
        s -= 1
        fe = batch.get("frontend_emb")
        total = s + (fe.shape[1] if fe is not None else 0)
        return jnp.broadcast_to(jnp.arange(total, dtype=jnp.int32), (b, total))

    def _token_nll(logits, batch):
        labels = batch["tokens"][:, 1:]
        if batch.get("frontend_emb") is not None:
            logits = logits[:, -labels.shape[1]:]
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logits.astype(jnp.float32),
                                 labels[..., None], axis=-1)[..., 0]
        return lse - ll                              # (B, S)

    def loss_from_output(logits, batch):
        return _token_nll(logits, batch).mean()

    def per_example_loss(logits, batch):
        return _token_nll(logits, batch).mean(axis=-1)

    def scores_from_output(logits):
        return jax.nn.softmax(logits.astype(jnp.float32), axis=-1)

    return SplitAdapter(model.cfg.name, seg_names, init, inputs, apply_seg,
                        loss_from_output, scores_from_output,
                        per_example_loss)


PRECISIONS = ("fp32", "bf16")


def cast_adapter(adapter: SplitAdapter, precision: str) -> SplitAdapter:
    """Mixed-precision view of an adapter: compute in bf16, master in fp32.

    With ``precision="bf16"`` every TRAINING segment application casts its
    floating params and activations to bfloat16 before the underlying
    ``apply_seg`` — so the forward/backward matmuls run in bf16 while the
    params the optimizer owns (and therefore FedAvg client averaging and
    the server-Adam moments) stay full fp32 masters: the cast sits inside
    the loss, so ``jax.grad`` cotangents flow back through the ``astype``
    and arrive fp32.  Losses are already reduced in fp32 by every adapter,
    and evaluation (``train=False``) is untouched — clients score with
    their own full-precision segments, matching the paper's eval protocol.

    Boundary specs inherit the cast (train-time smashed activations ARE
    bf16 on the wire), so transport byte accounting stays honest.
    ``precision="fp32"`` returns the adapter unchanged.
    """
    if precision not in PRECISIONS:
        raise ValueError(f"unknown precision {precision!r} "
                         f"(one of {PRECISIONS})")
    if precision == "fp32":
        return adapter

    def _cast(tree):
        return jax.tree.map(
            lambda l: l.astype(jnp.bfloat16)
            if jnp.issubdtype(jnp.asarray(l).dtype, jnp.floating) else l,
            tree)

    inner = adapter.apply_seg

    def apply_seg(seg, seg_params, x, batch, train=False):
        if not train:
            return inner(seg, seg_params, x, batch, train)
        return inner(seg, _cast(seg_params), _cast(x), batch, train)

    return dataclasses.replace(adapter, apply_seg=apply_seg)


def leaf_bytes(tree) -> int:
    return int(sum(int(np.prod(l.shape)) * l.dtype.itemsize
                   for l in jax.tree.leaves(tree)))


# ---------------------------------------------------------------------------
# stacked-tree helpers — per-client pytrees with a leading hospital axis
# ---------------------------------------------------------------------------

def stack_trees(trees):
    """List of identically-shaped pytrees -> one tree with leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def unstack_tree(tree, n):
    """Inverse of ``stack_trees``: leading axis back to a list of trees."""
    return [jax.tree.map(lambda x: x[i], tree) for i in range(n)]


def tree_take(tree, i):
    """Select hospital ``i``'s slice from a stacked tree (traceable)."""
    return jax.tree.map(lambda x: x[i], tree)


def tree_put(tree, i, sub):
    """Scatter ``sub`` back into hospital ``i``'s slice (traceable)."""
    return jax.tree.map(lambda x, y: x.at[i].set(y), tree, sub)


def tree_select(flag, new, old):
    """``new`` where ``flag`` (scalar bool) else ``old`` — the pad-and-mask
    engine's way of turning an invalid (padding) step into a no-op."""
    return jax.tree.map(lambda a, b: jnp.where(flag, a, b), new, old)
