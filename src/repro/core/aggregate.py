"""Pluggable model-update aggregation — the server side of every round.

Until PR 9 the FedAvg reduction was hard-coded in four places
(``base.tree_weighted_mean`` for the stepwise path, the jitted
``engine.stacked_weighted_mean`` for the per-epoch compiled path, an
inlined ``_weighted_mean`` in ``engine.make_fl_run``'s round scan, and a
host-side secagg special case branched inside ``FedAvg._aggregate``).
This module owns all of it behind one interface:

  * ``Aggregator.aggregate(stacked, weights, prev, ...)`` is TRACEABLE —
    it can live inside the whole-run round scan, so the multi-epoch run
    stays ONE XLA dispatch whatever the aggregation rule.
  * ``Aggregator.aggregate_trees`` / ``Aggregator.host`` are the
    host-callable forms the stepwise and per-epoch compiled paths use.
  * ``prev`` (the pre-round global params) makes zero-weight rounds well
    defined: a round where no client carries weight — Poisson sampling
    drawing nobody, or an all-phantom shard — keeps the previous globals
    instead of dividing by zero into NaNs.
  * ``scan_compatible=False`` (secagg: a host-side pairwise-mask
    protocol) tells the strategy to keep the per-round host loop.

Registered rules: ``weighted_mean`` (data-size FedAvg — bit-identical to
the pre-PR-9 code when weights are positive), ``secagg``, robust
``trimmed_mean`` / ``coordinate_median``, async ``staleness_discounted``
(stale updates re-weighted by rounds-behind), and two-tier
``hierarchical`` (region means, then an unweighted mean over regions).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# functional cores (moved verbatim from base.py / engine.py)
# ---------------------------------------------------------------------------

def tree_mean(trees):
    """Plain mean over a list of pytrees (host-side, eager)."""
    return jax.tree.map(lambda *xs: sum(xs) / len(xs), *trees)


def tree_weighted_mean(trees, weights, prev=None):
    """Data-size-weighted mean over a list of pytrees (host-side, eager).

    ``prev`` guards the all-zero-weight case: with no weight anywhere the
    round is a no-op and the previous params come back unchanged (without
    ``prev`` the guard falls back to the unweighted mean) — the pre-PR-9
    code divided by the zero sum and produced NaN params.
    """
    total = sum(weights)
    if total <= 0:
        return prev if prev is not None else tree_mean(trees)
    return jax.tree.map(
        lambda *xs: sum(w * x for w, x in zip(weights, xs)) / total, *trees)


def weighted_mean_normalized(stacked, w):
    """Normalized-weight mean over the leading hospital axis (traceable —
    shared by the jitted host-callable below and the in-scan FedAvg of
    ``engine.make_fl_run``)."""
    def leaf(x):
        wx = w.reshape((-1,) + (1,) * (x.ndim - 1))
        return (x.astype(jnp.float32) * wx).sum(axis=0).astype(x.dtype)

    return jax.tree.map(leaf, stacked)


def weighted_mean_guarded(stacked, weights, prev):
    """Traceable weighted mean with the zero-total guard: normalizes raw
    ``weights`` in-graph and selects ``prev`` wherever the round carries
    no weight at all (a no-client Poisson round, an all-phantom shard)."""
    wf = weights.astype(jnp.float32)
    total = wf.sum()
    w = wf / jnp.where(total > 0, total, 1.0)
    out = weighted_mean_normalized(stacked, w)
    return jax.tree.map(
        lambda a, p: jnp.where(total > 0, a, p.astype(a.dtype)), out, prev)


def mean_sync(stacked, w=None):
    """SFLv2-style client sync (traceable): every hospital gets the mean
    of all client segments.  ``w`` (normalized-to-sum weights, e.g. a
    placement's phantom mask) makes it a weighted mean so padding rows
    contribute nothing — phantom rows also RECEIVE the mean, which is
    harmless (they are never read and never weigh into future syncs)."""
    if w is None:
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x.mean(axis=0, keepdims=True),
                                       x.shape), stacked)
    wn = w.astype(jnp.float32) / w.astype(jnp.float32).sum()

    def leaf(x):
        wx = wn.reshape((-1,) + (1,) * (x.ndim - 1))
        m = (x.astype(jnp.float32) * wx).sum(axis=0,
                                             keepdims=True).astype(x.dtype)
        return jnp.broadcast_to(m, x.shape)

    return jax.tree.map(leaf, stacked)


@jax.jit
def _stacked_weighted_mean_jit(stacked, weights):
    w = weights.astype(jnp.float32) / weights.astype(jnp.float32).sum()
    return weighted_mean_normalized(stacked, w)


def stacked_weighted_mean(stacked, weights, prev=None):
    """Data-size-weighted FedAvg over the leading hospital axis — ONE
    fused program instead of per-leaf eager host ops over a list of
    trees (host-side aggregation cost grows with n_clients x n_leaves
    and was dwarfing the compiled epoch itself).  Zero-weight rows
    (placement phantoms) contribute nothing.  An all-zero weight vector
    returns ``prev`` (the zero-weight-round guard; unweighted mean when
    no ``prev`` is given) — checked host-side so the positive-weight
    program is byte-identical to the unguarded pre-PR-9 jit."""
    if float(np.sum(np.asarray(weights, np.float64))) <= 0:
        return prev if prev is not None else _mean_sync_collapse(stacked)
    return _stacked_weighted_mean_jit(stacked, jnp.asarray(weights))


@jax.jit
def _mean_sync_jit(stacked):
    return mean_sync(stacked)


@jax.jit
def _mean_sync_w_jit(stacked, w):
    return mean_sync(stacked, w)


@jax.jit
def _mean_sync_collapse(stacked):
    return jax.tree.map(lambda x: x.mean(axis=0), stacked)


def stacked_mean_sync(stacked, weights=None):
    """SFLv2-style client synchronization on the stacked hospital axis:
    every hospital gets the (optionally weighted — phantom rows excluded)
    mean of all client segments."""
    if weights is None:
        return _mean_sync_jit(stacked)
    return _mean_sync_w_jit(stacked, jnp.asarray(weights, jnp.float32))


# ---------------------------------------------------------------------------
# the Aggregator interface
# ---------------------------------------------------------------------------

class Aggregator:
    """One server-side aggregation rule.

    ``aggregate`` is the traceable core — ``stacked`` is a pytree with a
    leading hospital (or participation-slot) axis, ``weights`` the raw
    (unnormalized) per-row weights with zeros for phantom / empty rows,
    ``prev`` the pre-round globals returned verbatim when nothing
    carries weight.  ``staleness`` ([rows] rounds-behind, participation
    runs) and ``gids`` ([rows] global hospital ids, -1 for empty slots)
    are optional per-round context individual rules may use.
    """
    name = "base"
    scan_compatible = True

    def aggregate(self, stacked, weights, prev, staleness=None, gids=None):
        raise NotImplementedError

    # -- host-callable forms -------------------------------------------------
    def aggregate_trees(self, trees, weights, prev=None):
        """List-of-pytrees form for the stepwise host loop."""
        from repro.core.partition import stack_trees
        if prev is None:
            prev = trees[0]
        return self.host(stack_trees(trees), np.asarray(weights), prev)

    def host(self, stacked, weights, prev=None):
        """Stacked form for the per-epoch compiled path (jitted once)."""
        if prev is None:
            prev = jax.tree.map(lambda x: x[0], stacked)
        if not hasattr(self, "_host_jit"):
            self._host_jit = jax.jit(
                lambda s, w, p: self.aggregate(s, w, p))
        return self._host_jit(stacked, jnp.asarray(weights, jnp.float32),
                              prev)


class WeightedMean(Aggregator):
    """Data-size-weighted FedAvg — the paper's aggregation and the
    default.  Bit-identical to the pre-PR-9 hard-coded paths whenever
    any weight is positive (the zero-weight guard only reroutes rounds
    that previously produced NaNs)."""
    name = "weighted_mean"

    def aggregate(self, stacked, weights, prev, staleness=None, gids=None):
        return weighted_mean_guarded(stacked, weights, prev)

    def aggregate_trees(self, trees, weights, prev=None):
        return tree_weighted_mean(trees, weights, prev)

    def host(self, stacked, weights, prev=None):
        return stacked_weighted_mean(stacked, weights, prev)


class SecAggregator(Aggregator):
    """Pairwise-mask secure aggregation (host-side protocol).

    Wraps a ``repro.privacy.secagg.SecAgg`` group: per-client fixed-point
    masked uploads, modular server sum.  The mask exchange is a host
    round-trip, so it cannot fold into the round scan —
    ``scan_compatible=False`` keeps the owning strategy on its per-round
    path."""
    name = "secagg"
    scan_compatible = False

    def __init__(self, secagg):
        self.secagg = secagg

    def aggregate(self, stacked, weights, prev, staleness=None, gids=None):
        raise RuntimeError("secagg is a host-side protocol; use "
                           "aggregate_trees / host")

    def aggregate_trees(self, trees, weights, prev=None):
        if float(np.sum(np.asarray(weights, np.float64))) <= 0:
            return prev if prev is not None else tree_mean(trees)
        host = [jax.tree.map(np.asarray, t) for t in trees]
        agg = self.secagg.aggregate_weighted(host, list(weights))
        return jax.tree.map(lambda a, old: jnp.asarray(a, old.dtype), agg,
                            trees[0])

    def host(self, stacked, weights, prev=None):
        from repro.core.partition import unstack_tree
        n = self.secagg.n_clients
        trees = unstack_tree(stacked, n)
        # float64 weights: the fixed-point quantization of w / sum(w) must
        # match the stepwise path's Python-float division exactly
        w = [float(x) for x in np.asarray(weights, np.float64)[:n]]
        return self.aggregate_trees(trees, w, prev)


class TrimmedMean(Aggregator):
    """Coordinate-wise trimmed mean over rows with positive weight
    (Byzantine-robust; Yin et al. 2018).  Weights gate VALIDITY only —
    the surviving coordinates average unweighted, as the robustness
    guarantee requires.  Trims ``floor(trim * n_valid)`` from each end,
    capped so at least one row always survives."""
    name = "trimmed_mean"

    def __init__(self, trim: float = 0.1):
        if not 0.0 <= trim < 0.5:
            raise ValueError("trim must be in [0, 0.5)")
        self.trim = float(trim)

    def aggregate(self, stacked, weights, prev, staleness=None, gids=None):
        wf = weights.astype(jnp.float32)
        valid = wf > 0
        n_valid = valid.sum().astype(jnp.int32)
        k = jnp.minimum(jnp.floor(self.trim * n_valid).astype(jnp.int32),
                        jnp.maximum((n_valid - 1) // 2, 0))

        def leaf(x, p):
            C = x.shape[0]
            vshape = (C,) + (1,) * (x.ndim - 1)
            xs = jnp.where(valid.reshape(vshape), x.astype(jnp.float32),
                           jnp.inf)
            xs = jnp.sort(xs, axis=0)            # invalid rows sort last
            ranks = jnp.arange(C).reshape(vshape)
            keep = (ranks >= k) & (ranks < n_valid - k)
            denom = jnp.maximum(n_valid - 2 * k, 1).astype(jnp.float32)
            total = jnp.where(keep, xs, 0.0).sum(axis=0)
            return jnp.where(n_valid > 0, (total / denom).astype(x.dtype),
                             p.astype(x.dtype))

        return jax.tree.map(leaf, stacked, prev)


class CoordinateMedian(Aggregator):
    """Coordinate-wise median over rows with positive weight (robust;
    even counts average the two middle order statistics)."""
    name = "coordinate_median"

    def aggregate(self, stacked, weights, prev, staleness=None, gids=None):
        wf = weights.astype(jnp.float32)
        valid = wf > 0
        n_valid = valid.sum().astype(jnp.int32)
        lo = jnp.maximum((n_valid - 1) // 2, 0)
        hi = n_valid // 2

        def leaf(x, p):
            C = x.shape[0]
            vshape = (C,) + (1,) * (x.ndim - 1)
            xs = jnp.where(valid.reshape(vshape), x.astype(jnp.float32),
                           jnp.inf)
            xs = jnp.sort(xs, axis=0)
            med = (jnp.take(xs, lo, axis=0) + jnp.take(xs, hi, axis=0)) / 2
            return jnp.where(n_valid > 0, med.astype(x.dtype),
                             p.astype(x.dtype))

        return jax.tree.map(leaf, stacked, prev)


class StalenessDiscounted(Aggregator):
    """Async/buffered FedAvg: each update's data-size weight is further
    discounted by ``decay ** staleness`` — ``staleness`` counts the
    rounds a hospital sat out since it last contributed (0 for fresh or
    first-time updates), so rarely-sampled hospitals re-entering a
    participation run pull the globals less hard."""
    name = "staleness_discounted"

    def __init__(self, decay: float = 0.5):
        if not 0.0 < decay <= 1.0:
            raise ValueError("decay must be in (0, 1]")
        self.decay = float(decay)

    def aggregate(self, stacked, weights, prev, staleness=None, gids=None):
        w = weights.astype(jnp.float32)
        if staleness is not None:
            w = w * jnp.power(jnp.float32(self.decay),
                              staleness.astype(jnp.float32))
        return weighted_mean_guarded(stacked, w, prev)


class Hierarchical(Aggregator):
    """Two-tier region -> global aggregation: data-size-weighted mean
    WITHIN each region, then an UNWEIGHTED mean over non-empty regions —
    every region gets one vote regardless of cohort size (per-region
    fairness; a weight-proportional second tier would collapse to the
    flat weighted mean).  ``regions[g]`` maps global hospital ``g`` to
    its region; participation runs resolve slot rows through ``gids``.
    """
    name = "hierarchical"

    def __init__(self, regions):
        self.regions = tuple(int(r) for r in regions)
        if any(r < 0 for r in self.regions):
            raise ValueError("region ids must be >= 0")
        self.n_regions = max(self.regions) + 1 if self.regions else 0

    def aggregate(self, stacked, weights, prev, staleness=None, gids=None):
        reg = jnp.asarray(self.regions, jnp.int32)
        C = weights.shape[0]
        r = reg[:C] if gids is None else reg[jnp.maximum(gids, 0)]
        wf = weights.astype(jnp.float32)
        R = self.n_regions
        reg_w = jax.ops.segment_sum(wf, r, num_segments=R)        # [R]
        nonempty = (reg_w > 0).astype(jnp.float32)
        n_r = nonempty.sum()

        def leaf(x, p):
            flat = x.reshape(C, -1).astype(jnp.float32)
            s = jax.ops.segment_sum(flat * wf[:, None], r, num_segments=R)
            means = s / jnp.maximum(reg_w, 1e-12)[:, None]
            g = ((means * nonempty[:, None]).sum(axis=0)
                 / jnp.maximum(n_r, 1.0))
            return jnp.where(n_r > 0, g.reshape(x.shape[1:]).astype(x.dtype),
                             p.astype(x.dtype))

        return jax.tree.map(leaf, stacked, prev)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

AGGREGATORS: dict = {
    "weighted_mean": WeightedMean,
    "trimmed_mean": TrimmedMean,
    "coordinate_median": CoordinateMedian,
    "staleness_discounted": StalenessDiscounted,
    "hierarchical": Hierarchical,
}


def register(name: str, cls) -> None:
    """Add an ``Aggregator`` subclass to the registry."""
    AGGREGATORS[name] = cls


def make_aggregator(spec=None) -> Aggregator:
    """``None`` -> the default ``WeightedMean``; a registered name ->
    that rule with default parameters; an ``Aggregator`` instance passes
    through (the way to set ``trim`` / ``decay`` / ``regions``)."""
    if spec is None:
        return WeightedMean()
    if isinstance(spec, Aggregator):
        return spec
    if isinstance(spec, str):
        if spec not in AGGREGATORS:
            raise ValueError(f"unknown aggregator {spec!r}; "
                             f"registered: {sorted(AGGREGATORS)}")
        return AGGREGATORS[spec]()
    raise TypeError(f"aggregator spec must be None, a name, or an "
                    f"Aggregator, got {type(spec).__name__}")


__all__ = ["Aggregator", "WeightedMean", "SecAggregator", "TrimmedMean",
           "CoordinateMedian", "StalenessDiscounted", "Hierarchical",
           "AGGREGATORS", "register", "make_aggregator", "tree_mean",
           "tree_weighted_mean", "weighted_mean_normalized",
           "weighted_mean_guarded", "mean_sync", "stacked_weighted_mean",
           "stacked_mean_sync"]
