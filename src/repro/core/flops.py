"""Computation accounting (paper Tables 5/6): server FLOPs, average client
FLOPs, and model-averaging FLOPs, per epoch.

Per-segment forward FLOPs come from XLA's ``cost_analysis()`` of the jitted
segment application — the compiler's count, not a hand model.  Training
FLOPs use the standard fwd+bwd = 3x forward rule (backward ~ 2x forward for
matmul-dominated nets).  Averaging FLOPs are analytic: (n_clients adds + 1
scale) per parameter, counted once per epoch exactly as the paper does.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.core.partition import SplitAdapter
from repro.models.layers import param_count

TRAIN_FACTOR = 3.0     # fwd + bwd


def flops_of(fn, *args) -> float:
    """HLO FLOPs of fn(*args) on the current backend."""
    cost = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return float(cost.get("flops", 0.0))


@dataclasses.dataclass(frozen=True)
class FlopsProfile:
    method: str
    server_tflops: float
    avg_client_tflops: float
    averaging_mflops: float


def segment_fwd_flops(adapter: SplitAdapter, example_batch: dict) -> dict:
    """Forward FLOPs per segment, per batch."""
    params = adapter.init(jax.random.key(0))
    out = {}
    x = adapter.inputs(example_batch)
    for seg in adapter.seg_names:
        out[seg] = flops_of(
            lambda p, xx: adapter.apply_seg(seg, p, xx, example_batch, True),
            params[seg], x)
        x = adapter.apply_seg(seg, params[seg], x, example_batch, True)
    return out


def flops_per_epoch(method: str, adapter: SplitAdapter, example_batch: dict,
                    n_train: list[int], batch_size: int,
                    seg_fwd: dict | None = None) -> FlopsProfile:
    n_clients = len(n_train)
    total_batches = sum(n // batch_size for n in n_train)

    if seg_fwd is None:
        seg_fwd = segment_fwd_flops(adapter, example_batch)
    client_fwd = seg_fwd["front"] + seg_fwd.get("tail", 0.0)
    server_fwd = seg_fwd["middle"]
    full_fwd = sum(seg_fwd.values())

    params = jax.eval_shape(adapter.init, jax.random.key(0))
    p_all = param_count(params)
    p_client = param_count(params["front"]) + (
        param_count(params["tail"]) if adapter.nls else 0)
    p_middle = param_count(params["middle"])

    if method == "centralized":
        return FlopsProfile(method,
                            TRAIN_FACTOR * full_fwd * total_batches / 1e12,
                            0.0, 0.0)
    if method == "fl":
        avg_client = TRAIN_FACTOR * full_fwd * total_batches / n_clients
        averaging = p_all * (n_clients + 1)
        return FlopsProfile(method, 0.0, avg_client / 1e12, averaging / 1e6)

    server = TRAIN_FACTOR * server_fwd * total_batches
    avg_client = TRAIN_FACTOR * client_fwd * total_batches / n_clients
    averaging = 0.0
    if method.startswith("sflv2"):
        averaging = p_client * (n_clients + 1)
    elif method.startswith("sflv3"):
        averaging = p_middle * (n_clients + 1)
    elif method.startswith("sflv1"):
        averaging = p_all * (n_clients + 1)
    return FlopsProfile(method, server / 1e12, avg_client / 1e12,
                        averaging / 1e6)
