"""Mamba2 block via SSD (state-space duality, arXiv:2405.21060).

Chunked algorithm: the sequence is split into chunks of Q tokens; the
quadratic intra-chunk term is batched matmuls (MXU-friendly) and the
inter-chunk state recurrence is a ``lax.scan`` carrying (H, P, N) states.
``repro.kernels.ssd_scan`` holds the Pallas TPU twin of the intra-chunk
compute; this module is the pure-jnp implementation used as its oracle and
as the CPU path.

Decode path: O(1) per token — state update S <- dA * S + dt*x (x) B, output
y = C . S, matching the recurrent form of SSD exactly.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_model: int
    d_state: int = 128          # N
    head_dim: int = 64          # P
    expand: int = 2
    n_groups: int = 1           # G
    d_conv: int = 4
    chunk: int = 128            # Q (SSD chunk length)
    conv_gather: bool = True    # window-gather conv fuses better than
                                # shifted slices (measured, §Perf H2)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def mamba_init(key, cfg: MambaConfig, dtype=jnp.float32):
    kin, kconv, kdt, ka, kout, kn = jax.random.split(key, 6)
    d, di, h, g, n = (cfg.d_model, cfg.d_inner, cfg.n_heads,
                      cfg.n_groups, cfg.d_state)
    proj_out = 2 * di + 2 * g * n + h        # z, x, B, C, dt
    s = 1.0 / math.sqrt(d)
    p = {
        "in_proj": L._normal(kin, (d, proj_out), s, dtype),
        "conv_w": L._normal(kconv, (cfg.d_conv, cfg.conv_dim),
                            1.0 / math.sqrt(cfg.d_conv), dtype),
        "conv_b": jnp.zeros((cfg.conv_dim,), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        # A in (-exp range); stored as log for positivity
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": L._normal(kout, (di, d), 1.0 / math.sqrt(di), dtype),
    }
    a = {
        "in_proj": ("embed", "inner_proj"),
        "conv_w": (None, "inner_proj"),
        "conv_b": ("inner_proj",),
        "dt_bias": ("heads",),
        "A_log": ("heads",),
        "D": ("heads",),
        "norm_scale": ("inner",),
        "out_proj": ("inner", "embed"),
    }
    return p, a


def _segsum(log_a):
    """log_a: (..., Q).  Returns (..., Q, Q) with S[i,j] = sum_{j<m<=i} log_a[m]
    for j<=i, -inf above diagonal."""
    q = log_a.shape[-1]
    cs = jnp.cumsum(log_a, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]         # sum_{j<m<=i}
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk, init_state=None):
    """SSD forward.
    x: (b, l, h, p)   dt: (b, l, h) (post-softplus, >0)
    A: (h,) (positive; decay = exp(-dt*A))   B, C: (b, l, g, n)
    Returns y: (b, l, h, p), final_state: (b, h, p, n).
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    q = chunk
    assert l % q == 0, f"seq {l} not divisible by chunk {q}"
    nc = l // q
    rep = h // g

    xb = x * dt[..., None]                            # discretized input
    log_a = (-dt * A).astype(jnp.float32)             # (b, l, h) log decay
    xc = xb.reshape(b, nc, q, h, p)
    Bc = B.reshape(b, nc, q, g, n)
    Cc = C.reshape(b, nc, q, g, n)
    lac = log_a.reshape(b, nc, q, h)

    Brep = jnp.repeat(Bc, rep, axis=3).astype(jnp.float32)   # (b,nc,q,h,n)
    Crep = jnp.repeat(Cc, rep, axis=3).astype(jnp.float32)   # (b,nc,q,h,n)

    # --- intra-chunk (quadratic within chunk; Pallas kernel twin) ---
    seg = _segsum(lac.transpose(0, 1, 3, 2))          # (b, nc, h, q, q)
    Lmat = jnp.exp(seg)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Crep, Brep)
    y_intra = jnp.einsum("bchqk,bchqk,bckhp->bcqhp",
                         scores, Lmat, xc.astype(jnp.float32))

    # --- chunk summary states ---
    a_last = jnp.exp(lac.sum(axis=2))                 # (b, nc, h) total decay
    decay_to_end = jnp.exp(lac.sum(axis=2)[:, :, None, :] -
                           jnp.cumsum(lac, axis=2))   # (b, nc, q, h)
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchpn",
                        decay_to_end, Brep,
                        xc.astype(jnp.float32))       # (b, nc, h, p, n)

    # --- inter-chunk recurrence ---
    def body(s, inp):
        st, al = inp                                  # (b,h,p,n), (b,h)
        s_new = s * al[:, :, None, None] + st
        return s_new, s                               # emit state BEFORE chunk

    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final, prev_states = jax.lax.scan(
        body, s0, (states.transpose(1, 0, 2, 3, 4), a_last.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b, nc, h, p, n)

    # --- inter-chunk output ---
    decay_from_start = jnp.exp(jnp.cumsum(lac, axis=2))  # (b, nc, q, h)
    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                         Crep, prev_states, decay_from_start)
    y = (y_intra + y_inter).reshape(b, l, h, p)
    return y.astype(x.dtype), final


def mamba_apply(p, cfg: MambaConfig, x, cache=None, use_pallas=False):
    """x: (B, S, D).  cache: None or {"conv": (B, d_conv-1, conv_dim),
    "ssm": (B, H, P, N)} for single-token decode.  Returns (y, new_cache)."""
    b, s, d = x.shape
    di, h, g, n, pd = (cfg.d_inner, cfg.n_heads, cfg.n_groups,
                       cfg.d_state, cfg.head_dim)
    proj = x @ p["in_proj"].astype(x.dtype)           # (B,S,2di+2gn+h)
    z, xbc, dt_raw = jnp.split(proj, [di, di + cfg.conv_dim], axis=-1)
    xbc_in = xbc

    if cache is None:
        # causal depthwise conv via padding
        pad = jnp.zeros((b, cfg.d_conv - 1, cfg.conv_dim), xbc.dtype)
        xpad = jnp.concatenate([pad, xbc], axis=1)
        new_conv_state = xpad[:, -(cfg.d_conv - 1):, :] if cfg.d_conv > 1 else None
    else:
        xpad = jnp.concatenate([cache["conv"].astype(xbc.dtype), xbc], axis=1)
        new_conv_state = xpad[:, -(cfg.d_conv - 1):, :]

    if cfg.conv_gather:
        # legacy: materializes a (B, S, d_conv, C) window gather
        idx = jnp.arange(s)[:, None] + jnp.arange(cfg.d_conv)[None, :]
        win = xpad[:, idx, :]
        xbc = jax.nn.silu(jnp.einsum("bskc,kc->bsc", win,
                                     p["conv_w"].astype(xbc.dtype))
                          + p["conv_b"].astype(xbc.dtype))
    else:
        # depthwise causal conv as d_conv shifted scaled slices — avoids
        # the 4x activation blow-up of the window gather (§Perf)
        cw = p["conv_w"].astype(xbc.dtype)
        acc = xpad[:, :s, :] * cw[0]
        for k in range(1, cfg.d_conv):
            acc = acc + xpad[:, k:k + s, :] * cw[k]
        xbc = jax.nn.silu(acc + p["conv_b"].astype(xbc.dtype))

    xin, B_, C_ = jnp.split(xbc, [di, di + g * n], axis=-1)
    xin = xin.reshape(b, s, h, pd)
    B_ = B_.reshape(b, s, g, n)
    C_ = C_.reshape(b, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = jnp.exp(p["A_log"])                           # (H,) positive

    if cache is None or s > 1:
        # train, or (chained) prefill with an incoming cache state
        s0 = cache["ssm"] if cache is not None else None
        if use_pallas and s % cfg.chunk == 0 and s0 is None:
            from repro.kernels.ssd_scan import ops as ssd_ops
            y, final = ssd_ops.ssd(xin, dt, A, B_, C_, cfg.chunk)
        else:
            ch = cfg.chunk if s % cfg.chunk == 0 else _best_chunk(s)
            y, final = ssd_chunked(xin, dt, A, B_, C_, ch, init_state=s0)
        new_ssm = final
    else:
        # recurrent decode: S <- exp(-dt A) S + dt x B^T ; y = C . S
        S = cache["ssm"]                              # (B,H,P,N)
        da = jnp.exp(-dt[:, 0, :] * A)                # (B,H)
        Brep = jnp.repeat(B_, h // g, axis=2)[:, 0]   # (B,H,N)
        Crep = jnp.repeat(C_, h // g, axis=2)[:, 0]   # (B,H,N)
        xd = (xin[:, 0] * dt[:, 0, :, None]).astype(jnp.float32)  # (B,H,P)
        S = S * da[:, :, None, None] + jnp.einsum("bhp,bhn->bhpn", xd,
                                                  Brep.astype(jnp.float32))
        y = jnp.einsum("bhn,bhpn->bhp", Crep.astype(jnp.float32), S)
        y = y[:, None]                                # (B,1,H,P)
        new_ssm = S

    y = y + xin.astype(y.dtype) * p["D"][:, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    # gated RMSNorm (mamba2's norm-before-out-proj)
    y = L.rmsnorm_apply({"scale": p["norm_scale"]}, y * jax.nn.silu(z))
    out = y @ p["out_proj"].astype(x.dtype)
    if new_conv_state is None:
        new_conv_state = jnp.zeros((b, 0, cfg.conv_dim), x.dtype)
    return out, {"conv": new_conv_state, "ssm": new_ssm}


def _best_chunk(s: int) -> int:
    for c in (128, 64, 32, 16, 8, 4, 2, 1):
        if s % c == 0:
            return c
    return 1


def mamba_cache_init(cfg: MambaConfig, batch: int, dtype=jnp.bfloat16):
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.conv_dim), dtype),
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state),
                         jnp.float32),
    }
