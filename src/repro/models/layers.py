"""Neural-network primitives shared by every model family.

Everything is pure-functional: ``*_init`` builds a param pytree (dict of
arrays) plus a parallel *logical-axis* tree used by the launcher to derive
PartitionSpecs, and ``*_apply`` consumes it.  No flax/haiku — the cut-layer
partitioning of the paper (see ``repro.core.partition``) needs full control
over the param tree boundaries.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = Any  # pytree of jnp arrays
Axes = Any    # pytree (same structure) of tuples of logical axis names


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _normal(key, shape, scale, dtype):
    return (scale * jax.random.normal(key, shape)).astype(dtype)


def dense_init(key, in_dim: int, out_dim: int, *, axes=("in", "out"),
               dtype=jnp.float32, scale: float | None = None):
    """Weight-only dense layer (bias-free, llama-style)."""
    scale = (1.0 / math.sqrt(in_dim)) if scale is None else scale
    return {"w": _normal(key, (in_dim, out_dim), scale, dtype)}, {"w": axes}


def dense_apply(p, x):
    return x @ p["w"].astype(x.dtype)


def bias_dense_init(key, in_dim, out_dim, *, axes=("in", "out"), dtype=jnp.float32):
    kw, _ = jax.random.split(key)
    w, wa = dense_init(kw, in_dim, out_dim, axes=axes, dtype=dtype)
    w["b"] = jnp.zeros((out_dim,), dtype)
    wa["b"] = (axes[-1],)
    return w, wa


def bias_dense_apply(p, x):
    return x @ p["w"].astype(x.dtype) + p["b"].astype(x.dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(dim, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}, {"scale": ("embed",)}


def rmsnorm_apply(p, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(dim, dtype=jnp.float32):
    return ({"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)},
            {"scale": ("embed",), "bias": ("embed",)})


def layernorm_apply(p, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


def groupnorm_init(channels, dtype=jnp.float32):
    return ({"scale": jnp.ones((channels,), dtype), "bias": jnp.zeros((channels,), dtype)},
            {"scale": ("chan",), "bias": ("chan",)})


def groupnorm_apply(p, x, groups=8, eps=1e-5):
    """x: (B, H, W, C) — NHWC. Group norm (batch-stat free; see DESIGN.md)."""
    b, h, w, c = x.shape
    g = min(groups, c)
    while c % g:
        g -= 1
    dt = x.dtype
    xg = x.astype(jnp.float32).reshape(b, h, w, g, c // g)
    mu = jnp.mean(xg, axis=(1, 2, 4), keepdims=True)
    var = jnp.mean((xg - mu) ** 2, axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    y = xg.reshape(b, h, w, c) * p["scale"] + p["bias"]
    return y.astype(dt)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., : hd // 2], x[..., hd // 2:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, causal, optional sliding window, KV-cache decode)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    sliding_window: int | None = None   # None => full causal
    chunk_kv: int = 0                   # >0 => chunked (flash-style) jnp prefill


def attention_init(key, cfg: AttnConfig, dtype=jnp.float32):
    kq, kk, kv, ko = jax.random.split(key, 4)
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p, a = {}, {}
    p["wq"], a["wq"] = _normal(kq, (d, h * hd), 1 / math.sqrt(d), dtype), ("embed", "heads_flat")
    p["wk"], a["wk"] = _normal(kk, (d, kvh * hd), 1 / math.sqrt(d), dtype), ("embed", "kv_flat")
    p["wv"], a["wv"] = _normal(kv, (d, kvh * hd), 1 / math.sqrt(d), dtype), ("embed", "kv_flat")
    p["wo"], a["wo"] = _normal(ko, (h * hd, d), 1 / math.sqrt(h * hd), dtype), ("heads_flat", "embed")
    return p, a


def _full_causal_attn(q, k, v, positions, kv_positions, sliding_window):
    """q: (B,S,H,hd)  k,v: (B,T,KV,hd).  Returns (B,S,H,hd)."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    qg = q.reshape(b, s, kvh, rep, hd)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bsgrd,btgd->bgrst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = kv_positions[:, None, :] <= positions[:, :, None]      # (B,S,T)
    if sliding_window is not None:
        mask &= kv_positions[:, None, :] > positions[:, :, None] - sliding_window
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrst,btgd->bsgrd", w, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)


def _chunked_attn(q, k, v, positions, kv_positions, sliding_window, chunk):
    """Flash-style online-softmax over KV chunks (pure jnp; Pallas kernel is
    the TPU-target twin, see repro.kernels.flash_attention)."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    rep = h // kvh
    t = k.shape[1]
    n_chunks = (t + chunk - 1) // chunk
    pad = n_chunks * chunk - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)),
                               constant_values=jnp.iinfo(jnp.int32).max)
    kc = k.reshape(b, n_chunks, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, kvh, hd).transpose(1, 0, 2, 3, 4)
    pc = kv_positions.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    qg = q.reshape(b, s, kvh, rep, hd).astype(jnp.float32)
    scale = 1.0 / math.sqrt(hd)

    def body(carry, ch):
        m, l, acc = carry
        kb, vb, pb = ch
        logits = jnp.einsum("bsgrd,btgd->bgrst", qg, kb.astype(jnp.float32)) * scale
        mask = pb[:, None, :] <= positions[:, :, None]
        if sliding_window is not None:
            mask &= pb[:, None, :] > positions[:, :, None] - sliding_window
        logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bgrst,btgd->bgrsd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, rep, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kvh, rep, s), jnp.float32)
    a0 = jnp.zeros((b, kvh, rep, s, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, hd)
    return out.astype(q.dtype)


def attention_apply(p, cfg: AttnConfig, x, positions, cache=None,
                    use_pallas: bool = False):
    """x: (B, S, D).  ``cache``: None for train/prefill-without-cache, or
    {"k": (B,T,KV,hd), "v": ..., "pos": (B,T) int32, "index": int} for decode.
    Returns (out, new_cache)."""
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, s, kvh, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, s, kvh, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    if cache is not None:
        cl = cache["k"].shape[1]
        if s >= cl:
            # bulk prefill larger than a sliding-window ring cache: keep the
            # last `cl` tokens (their natural ring slots when cl | s) and
            # attend over the in-flight keys directly
            ck = k[:, -cl:].astype(cache["k"].dtype)
            cv = v[:, -cl:].astype(cache["v"].dtype)
            cpos = positions[:, -cl:].astype(jnp.int32)
            new_cache = {"k": ck, "v": cv, "pos": cpos,
                         "index": cache["index"] + s}
            k_all, v_all, kv_pos = k, v, positions
        else:
            # ring-buffer indexing: sliding-window caches allocate max_len ==
            # window and wrap (harmless for full caches: index < max_len)
            idx = cache["index"] % cl
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), idx, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), idx, axis=1)
            cpos = jax.lax.dynamic_update_slice_in_dim(cache["pos"], positions.astype(jnp.int32), idx, axis=1)
            new_cache = {"k": ck, "v": cv, "pos": cpos, "index": idx + s}
            k_all, v_all, kv_pos = ck, cv, cpos
    else:
        new_cache = None
        k_all, v_all, kv_pos = k, v, positions

    if use_pallas and cache is None and cfg.sliding_window is None:
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k_all, v_all, causal=True)
    elif cfg.chunk_kv and k_all.shape[1] > cfg.chunk_kv:
        out = _chunked_attn(q, k_all, v_all, positions, kv_pos,
                            cfg.sliding_window, cfg.chunk_kv)
    else:
        out = _full_causal_attn(q, k_all, v_all, positions, kv_pos,
                                cfg.sliding_window)
    out = out.reshape(b, s, h * hd) @ p["wo"].astype(x.dtype)
    return out, new_cache


def attention_cache_init(cfg: AttnConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.full((batch, max_len), jnp.iinfo(jnp.int32).max, jnp.int32),
        "index": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    p, a = {}, {}
    p["wi"], a["wi"] = _normal(k1, (d_model, d_ff), 1 / math.sqrt(d_model), dtype), ("embed", "ff")
    p["wg"], a["wg"] = _normal(k2, (d_model, d_ff), 1 / math.sqrt(d_model), dtype), ("embed", "ff")
    p["wo"], a["wo"] = _normal(k3, (d_ff, d_model), 1 / math.sqrt(d_ff), dtype), ("ff", "embed")
    return p, a


def swiglu_apply(p, x):
    h = jax.nn.silu(x @ p["wg"].astype(x.dtype)) * (x @ p["wi"].astype(x.dtype))
    return h @ p["wo"].astype(x.dtype)


def gelu_mlp_init(key, d_model, d_ff, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    p, a = {}, {}
    p["wi"], a["wi"] = _normal(k1, (d_model, d_ff), 1 / math.sqrt(d_model), dtype), ("embed", "ff")
    p["wo"], a["wo"] = _normal(k2, (d_ff, d_model), 1 / math.sqrt(d_ff), dtype), ("ff", "embed")
    return p, a


def gelu_mlp_apply(p, x):
    return jax.nn.gelu(x @ p["wi"].astype(x.dtype)) @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def embedding_init(key, vocab, d_model, dtype=jnp.float32):
    return ({"table": _normal(key, (vocab, d_model), 0.02, dtype)},
            {"table": ("vocab", "embed")})


def embedding_apply(p, ids, compute_dtype=None):
    out = jnp.take(p["table"], ids, axis=0)
    return out.astype(compute_dtype) if compute_dtype else out


def unembed_apply(p, x):
    """Tied or untied head: p['table'] (V, D) -> logits (..., V)."""
    return x @ p["table"].astype(x.dtype).T


# ---------------------------------------------------------------------------
# conv primitives (NHWC) for the paper's CNN families
# ---------------------------------------------------------------------------

def conv_init(key, in_ch, out_ch, ksize, *, dtype=jnp.float32):
    fan_in = in_ch * ksize * ksize
    return ({"w": _normal(key, (ksize, ksize, in_ch, out_ch), math.sqrt(2.0 / fan_in), dtype)},
            {"w": (None, None, "chan_in", "chan")})


def conv_apply(p, x, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def sepconv_init(key, in_ch, out_ch, ksize, *, dtype=jnp.float32):
    """Depthwise-separable conv (Xception building block)."""
    kd, kp = jax.random.split(key)
    p, a = {}, {}
    p["dw"] = _normal(kd, (ksize, ksize, 1, in_ch), math.sqrt(2.0 / (ksize * ksize)), dtype)
    a["dw"] = (None, None, None, "chan")
    p["pw"] = _normal(kp, (1, 1, in_ch, out_ch), math.sqrt(2.0 / in_ch), dtype)
    a["pw"] = (None, None, "chan_in", "chan")
    return p, a


def sepconv_apply(p, x, stride=1):
    x = jax.lax.conv_general_dilated(
        x, p["dw"].astype(x.dtype), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=x.shape[-1])
    return jax.lax.conv_general_dilated(
        x, p["pw"].astype(x.dtype), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def avg_pool(x, window=2, stride=2):
    return jax.lax.reduce_window(
        x, 0.0, jax.lax.add, (1, window, window, 1), (1, stride, stride, 1),
        "VALID") / (window * window)


def max_pool(x, window=2, stride=2, padding="VALID"):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1), (1, stride, stride, 1), padding)


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


def upsample2x(x):
    b, h, w, c = x.shape
    return jax.image.resize(x, (b, 2 * h, 2 * w, c), method="nearest")


def param_count(params) -> int:
    return int(sum(np.prod(a.shape) for a in jax.tree.leaves(params)))


def param_bytes(params) -> int:
    return int(sum(np.prod(a.shape) * a.dtype.itemsize for a in jax.tree.leaves(params)))
