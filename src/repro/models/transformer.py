"""Decoder-only transformer family covering all assigned architectures.

The model is represented as an ordered list of **segments** — the unit the
paper's cut-layer partitioning (repro.core.partition) operates on:

    front  : embedding (+ modality projector) + layers[0:cut]
    middle : layers[cut:L] + final norm (+ LM head in label-sharing mode)
    tail   : LM head (only in the non-label-sharing / U-shaped mode)

Within a segment, consecutive same-kind layers are grouped into **runs**
scanned with ``jax.lax.scan`` over stacked params, which keeps the HLO small
enough to lower 126-layer × 512-device programs on one CPU.  Layer kinds:
``dense`` (attn+SwiGLU), ``moe`` (attn+MoE), ``mamba`` (Mamba2/SSD) and
``shared`` (zamba2-style shared attention block — one param set, applied at
several depths, each application with its own KV cache).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 32000
    head_dim: int = 0               # 0 => d_model // n_heads
    rope_theta: float = 500000.0
    sliding_window: int | None = None
    chunk_kv: int = 0               # flash-style jnp chunking threshold
    # MoE
    n_experts: int = 0
    top_k: int = 0
    first_k_dense: int = 0          # leading dense layers (Kimi-K2 style)
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_chunk: int = 1024
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_n_groups: int = 1
    ssm_chunk: int = 128
    hybrid_attn_every: int = 0      # zamba2: shared attn block every k layers
    # perf variants (see EXPERIMENTS.md §Perf)
    vocab_pad_to: int = 0           # pad vocab to a multiple (shardability)
    mamba_conv_gather: bool = True   # gather conv (fuses better; §Perf H2)
    # modality frontend (stubbed per assignment)
    frontend: str | None = None     # None | "vision" | "audio"
    frontend_dim: int = 1024
    frontend_tokens: int = 256      # patches / audio frames per sample
    # split-learning defaults (the paper's technique)
    cut_layer: int = 4
    # numerics / training
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    # citation for the registry table
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        if not self.vocab_pad_to:
            return self.vocab_size
        m = self.vocab_pad_to
        return ((self.vocab_size + m - 1) // m) * m

    def attn_config(self) -> L.AttnConfig:
        return L.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.resolved_head_dim,
            rope_theta=self.rope_theta, sliding_window=self.sliding_window,
            chunk_kv=self.chunk_kv)

    def mamba_config(self) -> M.MambaConfig:
        return M.MambaConfig(
            d_model=self.d_model, d_state=self.ssm_state,
            head_dim=self.ssm_head_dim, n_groups=self.ssm_n_groups,
            chunk=self.ssm_chunk, conv_gather=self.mamba_conv_gather)

    def moe_config(self) -> MOE.MoEConfig:
        return MOE.MoEConfig(
            d_model=self.d_model, d_ff=self.d_ff, n_experts=self.n_experts,
            top_k=self.top_k, capacity_factor=self.capacity_factor,
            chunk=self.moe_chunk, n_shared_experts=self.n_shared_experts)


def layer_kinds(cfg: ModelConfig) -> list[str]:
    """Kind of each of the L layers, in depth order."""
    kinds = []
    for i in range(cfg.n_layers):
        if cfg.arch_type in ("ssm",):
            kinds.append("mamba")
        elif cfg.arch_type == "hybrid":
            kinds.append("mamba")
            if cfg.hybrid_attn_every and (i + 1) % cfg.hybrid_attn_every == 0:
                kinds.append("shared")
        elif cfg.n_experts and i >= cfg.first_k_dense:
            kinds.append("moe")
        else:
            kinds.append("dense")
    return kinds


@dataclasses.dataclass(frozen=True)
class RunSpec:
    kind: str
    count: int
    run_id: int


def group_runs(kinds: list[str]) -> list[RunSpec]:
    runs, rid = [], 0
    for k in kinds:
        if runs and runs[-1].kind == k and k != "shared":
            runs[-1] = RunSpec(k, runs[-1].count + 1, runs[-1].run_id)
        else:
            runs.append(RunSpec(k, 1, rid))
            rid += 1
    return runs


# ---------------------------------------------------------------------------
# per-layer blocks
# ---------------------------------------------------------------------------

def _dense_block_init(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    p, a = {}, {}
    p["ln1"], a["ln1"] = L.rmsnorm_init(cfg.d_model, dtype)
    p["attn"], a["attn"] = L.attention_init(k1, cfg.attn_config(), dtype)
    p["ln2"], a["ln2"] = L.rmsnorm_init(cfg.d_model, dtype)
    p["mlp"], a["mlp"] = L.swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p, a


def _dense_block_apply(p, cfg, x, positions, cache, use_pallas=False):
    h, new_cache = L.attention_apply(
        p["attn"], cfg.attn_config(), L.rmsnorm_apply(p["ln1"], x),
        positions, cache, use_pallas=use_pallas)
    x = x + h
    x = x + L.swiglu_apply(p["mlp"], L.rmsnorm_apply(p["ln2"], x))
    return x, new_cache, jnp.zeros((), jnp.float32)


def _moe_block_init(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    p, a = {}, {}
    p["ln1"], a["ln1"] = L.rmsnorm_init(cfg.d_model, dtype)
    p["attn"], a["attn"] = L.attention_init(k1, cfg.attn_config(), dtype)
    p["ln2"], a["ln2"] = L.rmsnorm_init(cfg.d_model, dtype)
    p["moe"], a["moe"] = MOE.moe_init(k2, cfg.moe_config(), dtype)
    return p, a


def _moe_block_apply(p, cfg, x, positions, cache, use_pallas=False):
    h, new_cache = L.attention_apply(
        p["attn"], cfg.attn_config(), L.rmsnorm_apply(p["ln1"], x),
        positions, cache, use_pallas=use_pallas)
    x = x + h
    y, aux = MOE.moe_apply(p["moe"], cfg.moe_config(),
                           L.rmsnorm_apply(p["ln2"], x))
    x = x + y
    aux_loss = 0.01 * aux["lb_loss"] + 0.001 * aux["z_loss"]
    return x, new_cache, aux_loss.astype(jnp.float32)


def _mamba_block_init(key, cfg: ModelConfig, dtype):
    p, a = {}, {}
    p["ln"], a["ln"] = L.rmsnorm_init(cfg.d_model, dtype)
    p["mamba"], a["mamba"] = M.mamba_init(key, cfg.mamba_config(), dtype)
    return p, a


def _mamba_block_apply(p, cfg, x, positions, cache, use_pallas=False):
    h, new_cache = M.mamba_apply(p["mamba"], cfg.mamba_config(),
                                 L.rmsnorm_apply(p["ln"], x), cache,
                                 use_pallas=use_pallas)
    return x + h, new_cache, jnp.zeros((), jnp.float32)


_BLOCK_INIT = {"dense": _dense_block_init, "moe": _moe_block_init,
               "mamba": _mamba_block_init, "shared": _dense_block_init}
_BLOCK_APPLY = {"dense": _dense_block_apply, "moe": _moe_block_apply,
                "mamba": _mamba_block_apply, "shared": _dense_block_apply}


def _block_cache_init(kind, cfg: ModelConfig, batch, max_len, dtype):
    if kind in ("dense", "moe", "shared"):
        if cfg.sliding_window:
            # ring buffer: a sliding-window cache never needs more than the
            # window (this is what qualifies llama4-scout for long_500k)
            max_len = min(max_len, cfg.sliding_window)
        return L.attention_cache_init(cfg.attn_config(), batch, max_len, dtype)
    return M.mamba_cache_init(cfg.mamba_config(), batch, dtype)


# ---------------------------------------------------------------------------
# runs (scanned stacks of same-kind layers)
# ---------------------------------------------------------------------------

def _run_init(key, spec: RunSpec, cfg: ModelConfig, dtype):
    if spec.kind == "shared":       # params live in the segment's shared slot
        return None, None
    keys = jax.random.split(key, spec.count)
    init = _BLOCK_INIT[spec.kind]
    p, a = jax.vmap(lambda k: init(k, cfg, dtype)[0])(keys), None
    _, a = init(keys[0], cfg, dtype)
    a = jax.tree.map(lambda ax: ("layers",) + tuple(ax), a,
                     is_leaf=lambda v: isinstance(v, tuple))
    return p, a


def _run_apply(run_p, shared_p, spec: RunSpec, cfg: ModelConfig, x,
               positions, cache, use_pallas=False, remat=False):
    apply = _BLOCK_APPLY[spec.kind]
    if spec.kind == "shared":
        x, new_cache, aux = apply(shared_p, cfg, x, positions, cache,
                                  use_pallas)
        return x, new_cache, aux

    def body(carry, layer):
        xc, aux = carry
        lp, lc = layer
        xc, nc, a = apply(lp, cfg, xc, positions, lc, use_pallas)
        return (xc, aux + a), nc

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    (x, aux), new_cache = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (run_p, cache))
    return x, new_cache, aux


def _run_cache_init(spec: RunSpec, cfg: ModelConfig, batch, max_len, dtype):
    one = _block_cache_init(spec.kind, cfg, batch, max_len, dtype)
    if spec.kind == "shared":
        return one
    return jax.tree.map(
        lambda v: jnp.broadcast_to(v, (spec.count,) + v.shape).copy(), one)


# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SegmentDef:
    name: str                         # front | middle | tail
    runs: tuple[RunSpec, ...]         # layer runs inside this segment
    has_embed: bool = False
    has_frontend: bool = False
    has_final_norm: bool = False
    has_head: bool = False
    has_shared: bool = False          # owns the shared-attn param set


def _segment_init(key, seg: SegmentDef, cfg: ModelConfig):
    dtype = cfg.param_dtype
    p, a = {}, {}
    keys = jax.random.split(key, len(seg.runs) + 4)
    if seg.has_embed:
        p["embed"], a["embed"] = L.embedding_init(keys[-1], cfg.padded_vocab,
                                                  cfg.d_model, dtype)
    if seg.has_frontend:
        p["projector"], a["projector"] = L.dense_init(
            keys[-2], cfg.frontend_dim, cfg.d_model,
            axes=("frontend", "embed"), dtype=dtype)
    if seg.has_shared:
        p["shared_block"], a["shared_block"] = _dense_block_init(
            keys[-3], cfg, dtype)
    for i, spec in enumerate(seg.runs):
        if spec.kind == "shared":
            continue
        p[f"run_{spec.run_id}"], a[f"run_{spec.run_id}"] = _run_init(
            keys[i], spec, cfg, dtype)
    if seg.has_final_norm:
        p["final_norm"], a["final_norm"] = L.rmsnorm_init(cfg.d_model, dtype)
    if seg.has_head:
        p["head"], a["head"] = L.dense_init(
            keys[-4], cfg.d_model, cfg.padded_vocab,
            axes=("embed", "vocab"), dtype=dtype)
    return p, a


def _segment_cache_init(seg: SegmentDef, cfg: ModelConfig, batch, max_len,
                        dtype=jnp.bfloat16):
    return {f"cache_{s.run_id}": _run_cache_init(s, cfg, batch, max_len, dtype)
            for s in seg.runs}


def _segment_apply(p, seg: SegmentDef, cfg: ModelConfig, x, ctx):
    """x: token ids (B,S) if seg.has_embed else hidden (B,S,D).
    ctx: dict(positions, cache[segment] or None, use_pallas, train).
    Returns (x, new_seg_cache, aux_loss)."""
    positions = ctx["positions"]
    cache = ctx.get("cache")
    use_pallas = ctx.get("use_pallas", False)
    remat = cfg.remat and ctx.get("train", False)
    aux = jnp.zeros((), jnp.float32)

    if seg.has_embed:
        tok_emb = L.embedding_apply(p["embed"], x, cfg.compute_dtype)
        if seg.has_frontend and ctx.get("frontend_emb") is not None:
            # decode steps past the prefix pass no frontend embeddings
            pe = ctx["frontend_emb"].astype(cfg.compute_dtype)
            pe = L.dense_apply(p["projector"], pe)
            x = jnp.concatenate([pe, tok_emb], axis=1)
        else:
            x = tok_emb
    new_cache = {}
    for spec in seg.runs:
        rc = cache[f"cache_{spec.run_id}"] if cache is not None else None
        run_p = p.get(f"run_{spec.run_id}")
        shared_p = p.get("shared_block") or ctx.get("shared_block")
        x, nc, a = _run_apply(run_p, shared_p, spec, cfg, x, positions, rc,
                              use_pallas=use_pallas, remat=remat)
        aux = aux + a
        if cache is not None:
            new_cache[f"cache_{spec.run_id}"] = nc
    if seg.has_final_norm:
        x = L.rmsnorm_apply(p["final_norm"], x)
    if seg.has_head:
        x = L.dense_apply(p["head"], x)
    return x, (new_cache if cache is not None else None), aux


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TransformerLM:
    cfg: ModelConfig
    segments: tuple[SegmentDef, ...]

    # ---- construction -----------------------------------------------------
    @staticmethod
    def build(cfg: ModelConfig, cut: int | None = None,
              nls: bool = False) -> "TransformerLM":
        """Split the layer stack at ``cut`` (paper's cut layer).  ``nls``
        adds the U-shaped client tail holding the LM head."""
        cut = cfg.cut_layer if cut is None else cut
        kinds = layer_kinds(cfg)
        cut = max(0, min(cut, len(kinds)))
        runs = group_runs(kinds)
        # re-split runs at the cut boundary (cut counts *layers incl shared*)
        front_runs, middle_runs, seen = [], [], 0
        for r in runs:
            if seen + r.count <= cut:
                front_runs.append(r); seen += r.count
            elif seen >= cut:
                middle_runs.append(r)
            else:
                front_runs.append(RunSpec(r.kind, cut - seen, r.run_id))
                middle_runs.append(RunSpec(r.kind, r.count - (cut - seen),
                                           r.run_id + 1000))
                seen = cut
        shared_in_front = any(r.kind == "shared" for r in front_runs)
        shared_in_middle = any(r.kind == "shared" for r in middle_runs)
        segs = [SegmentDef("front", tuple(front_runs), has_embed=True,
                           has_frontend=cfg.frontend is not None,
                           has_shared=shared_in_front)]
        segs.append(SegmentDef("middle", tuple(middle_runs),
                               has_final_norm=True, has_head=not nls,
                               has_shared=shared_in_middle
                               and not shared_in_front))
        if nls:
            segs.append(SegmentDef("tail", (), has_head=True))
        return TransformerLM(cfg, tuple(segs))

    # ---- params -----------------------------------------------------------
    def init(self, key):
        params, axes = {}, {}
        keys = jax.random.split(key, len(self.segments))
        for k, seg in zip(keys, self.segments):
            params[seg.name], axes[seg.name] = _segment_init(k, seg, self.cfg)
        return params, axes

    def init_params(self, key):
        return self.init(key)[0]

    # ---- caches -----------------------------------------------------------
    def cache_init(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        return {seg.name: _segment_cache_init(seg, self.cfg, batch, max_len,
                                              dtype)
                for seg in self.segments}

    # ---- forward ----------------------------------------------------------
    def apply(self, params, tokens, *, positions=None, cache=None,
              frontend_emb=None, use_pallas=False, train=False,
              segment_range=(0, None), boundary_fn=None):
        """Full or partial (segment_range) forward.
        tokens: (B,S) int32.  Returns (logits_or_hidden, new_cache, aux)."""
        b, s = tokens.shape[:2]
        if positions is None:
            total = s + (frontend_emb.shape[1]
                         if frontend_emb is not None else 0)
            positions = jnp.broadcast_to(jnp.arange(total, dtype=jnp.int32),
                                         (b, total))
        shared_block = None
        for seg in self.segments:
            if seg.has_shared and seg.name in params:
                shared_block = params[seg.name].get("shared_block")
        x = tokens
        start, stop = segment_range
        stop = len(self.segments) if stop is None else stop
        new_cache = dict(cache) if cache is not None else None
        aux = jnp.zeros((), jnp.float32)
        last = len(self.segments[start:stop]) - 1
        for si, seg in enumerate(self.segments[start:stop]):
            ctx = {"positions": positions,
                   "cache": cache[seg.name] if cache is not None else None,
                   "use_pallas": use_pallas, "train": train,
                   "frontend_emb": frontend_emb,
                   "shared_block": shared_block}
            x, seg_cache, a = _segment_apply(params[seg.name], seg, self.cfg,
                                             x, ctx)
            aux = aux + a
            if cache is not None:
                new_cache[seg.name] = seg_cache
            if boundary_fn is not None and si != last:
                # the paper's client->server link (e.g. int8 compression)
                x = boundary_fn(x)
        return x, new_cache, aux

    # ---- losses -----------------------------------------------------------
    def loss(self, params, batch, *, train=True, use_pallas=False,
             boundary_fn=None):
        """Next-token xent.  batch: {tokens (B,S), [frontend_emb]}."""
        tokens = batch["tokens"]
        logits, _, aux = self.apply(
            params, tokens[:, :-1], frontend_emb=batch.get("frontend_emb"),
            train=train, use_pallas=use_pallas, boundary_fn=boundary_fn)
        labels = tokens[:, 1:]
        if self.cfg.frontend is not None:
            # frontend tokens are prefix positions; only score text tokens
            logits = logits[:, -labels.shape[1]:]
        logits = logits.astype(jnp.float32)
        if self.cfg.padded_vocab != self.cfg.vocab_size:
            # mask padding slots out of the softmax
            pad_mask = jnp.arange(self.cfg.padded_vocab) >= self.cfg.vocab_size
            logits = jnp.where(pad_mask, -1e30, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        return (lse - ll).mean() + aux
