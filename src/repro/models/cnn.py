"""The paper's model families: DenseNet-121 and U-Net (Xception-style
encoder), expressed as an ordered list of *units* so the cut-layer split of
repro.core.partition applies directly ("first 4 layers at the client" ==
units[0:4]).

Activations crossing a segment boundary may be a pytree: the U-Net client
segment emits (hidden, skip_list) — the skip connections crossing the cut are
exactly why the paper measures enormous U-Net communication (774 GB/epoch).

GroupNorm replaces BatchNorm (batch-stat-free; avoids running-stat
synchronization ambiguity across virtual clients — noted in DESIGN.md; the
method ordering C1-C6 does not depend on the norm flavor).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import layers as L

Unit = tuple[str, Callable, Callable]   # (name, init(key)->(p,a), apply(p,x)->x)


# ---------------------------------------------------------------------------
# generic unit-list CNN with cut-layer segments
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CNNModel:
    name: str
    units: tuple[Unit, ...]
    cut: int                       # units[0:cut] -> client (front)
    nls: bool                      # True: last unit -> client tail
    head_from: str = "logits"      # how loss reads the output

    @property
    def seg_bounds(self):
        n = len(self.units)
        tail_start = n - 1 if self.nls else n
        return (0, self.cut), (self.cut, tail_start), (tail_start, n)

    @property
    def seg_names(self):
        return ("front", "middle", "tail") if self.nls else ("front", "middle")

    def init(self, key):
        params, axes = {}, {}
        bounds = dict(zip(("front", "middle", "tail"), self.seg_bounds))
        keys = jax.random.split(key, len(self.units))
        for seg in self.seg_names:
            lo, hi = bounds[seg]
            p, a = {}, {}
            for i in range(lo, hi):
                nm, init_fn, _ = self.units[i]
                p[nm], a[nm] = init_fn(keys[i])
            params[seg], axes[seg] = p, a
        return params, axes

    def init_params(self, key):
        return self.init(key)[0]

    def apply_segment(self, seg_params, seg: str, x, train=False):
        bounds = dict(zip(("front", "middle", "tail"), self.seg_bounds))
        lo, hi = bounds[seg]
        for i in range(lo, hi):
            nm, _, apply_fn = self.units[i]
            x = apply_fn(seg_params[nm], x)
        return x

    def apply(self, params, x, train=False):
        for seg in self.seg_names:
            x = self.apply_segment(params[seg], seg, x, train)
        return x

    def loss(self, params, batch, train=True):
        logits = self.apply(params, batch["image"], train)
        return bce_loss(logits, batch["label"])

    def predict(self, params, x):
        return jax.nn.sigmoid(self.apply(params, x).astype(jnp.float32))


def bce_loss(logits, labels):
    logits = logits.reshape(-1).astype(jnp.float32)
    labels = labels.reshape(-1).astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


# ---------------------------------------------------------------------------
# DenseNet
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DenseNetConfig:
    name: str = "densenet"
    growth: int = 32
    blocks: tuple[int, ...] = (6, 12, 24, 16)     # DenseNet-121
    stem_ch: int = 64
    compression: float = 0.5
    in_ch: int = 1
    n_classes: int = 1
    cut_layer: int = 4           # paper: first 4 layers at the client
    dtype: Any = jnp.float32


def _dense_layer(cfg: DenseNetConfig, in_ch: int):
    """norm-act-conv1x1(4g) + norm-act-conv3x3(g), concat."""
    g = cfg.growth

    def init(key):
        k1, k2 = jax.random.split(key)
        p, a = {}, {}
        p["n1"], a["n1"] = L.groupnorm_init(in_ch, cfg.dtype)
        p["c1"], a["c1"] = L.conv_init(k1, in_ch, 4 * g, 1, dtype=cfg.dtype)
        p["n2"], a["n2"] = L.groupnorm_init(4 * g, cfg.dtype)
        p["c2"], a["c2"] = L.conv_init(k2, 4 * g, g, 3, dtype=cfg.dtype)
        return p, a

    def apply(p, x):
        h = jax.nn.relu(L.groupnorm_apply(p["n1"], x))
        h = L.conv_apply(p["c1"], h)
        h = jax.nn.relu(L.groupnorm_apply(p["n2"], h))
        h = L.conv_apply(p["c2"], h)
        return jnp.concatenate([x, h], axis=-1)

    return init, apply


def _transition(cfg: DenseNetConfig, in_ch: int, out_ch: int):
    def init(key):
        p, a = {}, {}
        p["n"], a["n"] = L.groupnorm_init(in_ch, cfg.dtype)
        p["c"], a["c"] = L.conv_init(key, in_ch, out_ch, 1, dtype=cfg.dtype)
        return p, a

    def apply(p, x):
        h = jax.nn.relu(L.groupnorm_apply(p["n"], x))
        h = L.conv_apply(p["c"], h)
        return L.avg_pool(h, 2, 2)

    return init, apply


def build_densenet(cfg: DenseNetConfig, cut: int | None = None,
                   nls: bool = False) -> CNNModel:
    units: list[Unit] = []

    def stem_init(key):
        p, a = {}, {}
        p["c"], a["c"] = L.conv_init(key, cfg.in_ch, cfg.stem_ch, 7,
                                     dtype=cfg.dtype)
        p["n"], a["n"] = L.groupnorm_init(cfg.stem_ch, cfg.dtype)
        return p, a

    def stem_apply(p, x):
        h = L.conv_apply(p["c"], x, stride=2)
        h = jax.nn.relu(L.groupnorm_apply(p["n"], h))
        return L.max_pool(h, 3, 2, "SAME")

    units.append(("stem", stem_init, stem_apply))
    ch = cfg.stem_ch
    for bi, n_layers in enumerate(cfg.blocks):
        for li in range(n_layers):
            init, apply = _dense_layer(cfg, ch)
            units.append((f"b{bi}_l{li}", init, apply))
            ch += cfg.growth
        if bi != len(cfg.blocks) - 1:
            out = int(ch * cfg.compression)
            init, apply = _transition(cfg, ch, out)
            units.append((f"t{bi}", init, apply))
            ch = out

    final_ch = ch

    def head_init(key):
        p, a = {}, {}
        p["n"], a["n"] = L.groupnorm_init(final_ch, cfg.dtype)
        p["fc"], a["fc"] = L.bias_dense_init(key, final_ch, cfg.n_classes,
                                             axes=("chan", "classes"),
                                             dtype=cfg.dtype)
        return p, a

    def head_apply(p, x):
        h = jax.nn.relu(L.groupnorm_apply(p["n"], x))
        h = L.global_avg_pool(h)
        return L.bias_dense_apply(p["fc"], h)

    units.append(("head", head_init, head_apply))
    return CNNModel(cfg.name, tuple(units),
                    cut=cfg.cut_layer if cut is None else cut, nls=nls)


# ---------------------------------------------------------------------------
# U-Net (depthwise-separable / Xception-flavoured encoder)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class UNetConfig:
    name: str = "unet"
    widths: tuple[int, ...] = (32, 64, 128, 256)   # encoder pyramid
    in_ch: int = 1
    n_classes: int = 1
    cut_layer: int = 2           # paper: first 6 of a deeper net; scaled here
    dtype: Any = jnp.float32


def _enc_block(cfg: UNetConfig, in_ch: int, out_ch: int, down: bool):
    def init(key):
        k1, k2 = jax.random.split(key)
        p, a = {}, {}
        p["c1"], a["c1"] = L.sepconv_init(k1, in_ch, out_ch, 3, dtype=cfg.dtype)
        p["n1"], a["n1"] = L.groupnorm_init(out_ch, cfg.dtype)
        p["c2"], a["c2"] = L.sepconv_init(k2, out_ch, out_ch, 3, dtype=cfg.dtype)
        p["n2"], a["n2"] = L.groupnorm_init(out_ch, cfg.dtype)
        return p, a

    def apply(p, state):
        x, skips = state
        h = jax.nn.relu(L.groupnorm_apply(p["n1"], L.sepconv_apply(p["c1"], x)))
        h = jax.nn.relu(L.groupnorm_apply(p["n2"], L.sepconv_apply(p["c2"], h)))
        if down:                       # bottleneck (no down) adds no skip
            skips = skips + (h,)
            h = L.max_pool(h, 2, 2)
        return (h, skips)

    return init, apply


def _dec_block(cfg: UNetConfig, in_ch: int, skip_ch: int, out_ch: int):
    def init(key):
        k1, k2 = jax.random.split(key)
        p, a = {}, {}
        p["c1"], a["c1"] = L.sepconv_init(k1, in_ch + skip_ch, out_ch, 3,
                                          dtype=cfg.dtype)
        p["n1"], a["n1"] = L.groupnorm_init(out_ch, cfg.dtype)
        p["c2"], a["c2"] = L.sepconv_init(k2, out_ch, out_ch, 3, dtype=cfg.dtype)
        p["n2"], a["n2"] = L.groupnorm_init(out_ch, cfg.dtype)
        return p, a

    def apply(p, state):
        x, skips = state
        skip = skips[-1]
        skips = skips[:-1]
        x = L.upsample2x(x)
        x = jnp.concatenate([x, skip], axis=-1)
        h = jax.nn.relu(L.groupnorm_apply(p["n1"], L.sepconv_apply(p["c1"], x)))
        h = jax.nn.relu(L.groupnorm_apply(p["n2"], L.sepconv_apply(p["c2"], h)))
        return (h, skips)

    return init, apply


def build_unet(cfg: UNetConfig, cut: int | None = None,
               nls: bool = False) -> CNNModel:
    """Classification-via-segmentation U-Net (paper §3.2): the seg head's
    logit map is pooled into an image-level probability."""
    units: list[Unit] = []

    def lift_init(key):
        return {}, {}

    def lift_apply(p, x):
        return (x, ()) if not isinstance(x, tuple) else x

    units.append(("lift", lift_init, lift_apply))

    chans = [cfg.in_ch] + list(cfg.widths)
    for i, (ci, co) in enumerate(zip(chans[:-1], chans[1:])):
        down = i != len(cfg.widths) - 1
        init, apply = _enc_block(cfg, ci, co, down)
        units.append((f"enc{i}", init, apply))

    ws = list(cfg.widths)
    dec_in = ws[-1]
    for i in range(len(ws) - 2, -1, -1):
        init, apply = _dec_block(cfg, dec_in, ws[i], ws[i])
        units.append((f"dec{i}", init, apply))
        dec_in = ws[i]

    def head_init(key):
        p, a = {}, {}
        p["c"], a["c"] = L.conv_init(key, dec_in, cfg.n_classes, 1,
                                     dtype=cfg.dtype)
        return p, a

    def head_apply(p, state):
        x, _ = state
        seg = L.conv_apply(p["c"], x)                 # (B,H,W,1) logit map
        # smooth-max pooling -> image-level logit
        return jax.nn.logsumexp(seg.reshape(seg.shape[0], -1), axis=-1,
                                keepdims=True) - math.log(
                                    seg.shape[1] * seg.shape[2])

    units.append(("head", head_init, head_apply))
    return CNNModel(cfg.name, tuple(units),
                    cut=cfg.cut_layer if cut is None else cut, nls=nls)
