"""Mixture-of-Experts layer (GShard-style capacity dispatch).

Design notes (see DESIGN.md §4/§5):
* top-k routing with a fixed per-expert capacity, expressed as dense one-hot
  einsums — fully SPMD-shardable (expert dim -> 'model' mesh axis).
* To bound the transient dispatch tensor on trillion-param configs
  (kimi-k2: 384 experts, 1M tokens/step), tokens are processed in fixed-size
  chunks via ``lax.scan``: the (chunk, E, cap) one-hot stays a few MB while
  FLOPs/bytes accounting remains exact.
* Aux losses: load-balance (Switch) + router z-loss, returned for logging.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int               # per-expert hidden dim
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    chunk: int = 1024       # tokens per dispatch chunk
    n_shared_experts: int = 0   # dense "shared expert" (DeepSeek/Kimi style)


def moe_init(key, cfg: MoEConfig, dtype=jnp.float32):
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    s = 1.0 / math.sqrt(d)
    p = {
        "router": L._normal(kr, (d, e), s, jnp.float32),
        "wi": L._normal(k1, (e, d, f), s, dtype),
        "wg": L._normal(k2, (e, d, f), s, dtype),
        "wo": L._normal(k3, (e, f, d), 1.0 / math.sqrt(f), dtype),
    }
    a = {
        "router": ("embed", "experts_r"),
        "wi": ("experts", "embed", "ff"),
        "wg": ("experts", "embed", "ff"),
        "wo": ("experts", "ff", "embed"),
    }
    if cfg.n_shared_experts:
        sp, sa = L.swiglu_init(ks, d, f * cfg.n_shared_experts, dtype=dtype)
        p["shared"], a["shared"] = sp, sa
    return p, a


def _capacity(chunk_tokens: int, cfg: MoEConfig) -> int:
    cap = int(math.ceil(chunk_tokens * cfg.top_k / cfg.n_experts
                        * cfg.capacity_factor))
    return max(cap, 1)


def _dispatch_chunk(p, cfg: MoEConfig, x):
    """x: (T, D) one chunk. Returns (y, aux) with y: (T, D)."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(t, cfg)

    logits = (x.astype(jnp.float32) @ p["router"])            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, k)                      # (T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(topi, e, dtype=jnp.float32)        # (T, k, E)
    # position of each (token, slot) within its expert queue, token-major
    flat = onehot.reshape(t * k, e)
    pos = jnp.cumsum(flat, axis=0) - flat                      # (T*k, E)
    pos = (pos * flat).sum(-1).reshape(t, k).astype(jnp.int32)  # (T, k)
    in_cap = (pos < cap)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * in_cap[..., None]
    # dispatch: (T, E, cap)
    dispatch = jnp.einsum("tke,tkc->tec", onehot, pos_oh)
    combine = jnp.einsum("tke,tkc,tk->tec", onehot, pos_oh, topv)

    xin = jnp.einsum("tec,td->ecd", dispatch.astype(x.dtype), x)   # (E,cap,D)
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["wg"].astype(x.dtype)))
         * jnp.einsum("ecd,edf->ecf", xin, p["wi"].astype(x.dtype)))
    xout = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))  # (E,cap,D)
    y = jnp.einsum("tec,ecd->td", combine.astype(x.dtype), xout)

    # aux losses
    me = probs.mean(0)                                         # (E,)
    ce = onehot.sum(1).mean(0)                                 # fraction routed
    lb_loss = e * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - in_cap.mean()
    return y, {"lb_loss": lb_loss, "z_loss": z_loss, "dropped": dropped}


def moe_apply(p, cfg: MoEConfig, x):
    """x: (B, S, D) -> (y, aux)."""
    b, s, d = x.shape
    tok = x.reshape(b * s, d)
    t = tok.shape[0]
    chunk = min(cfg.chunk, t)
    n_chunks = (t + chunk - 1) // chunk
    pad = n_chunks * chunk - t
    if pad:
        tok = jnp.pad(tok, ((0, pad), (0, 0)))
    tok = tok.reshape(n_chunks, chunk, d)

    def body(_, xc):
        y, aux = _dispatch_chunk(p, cfg, xc)
        return None, (y, aux)

    _, (ys, auxs) = jax.lax.scan(body, None, tok)
    y = ys.reshape(n_chunks * chunk, d)[:t].reshape(b, s, d)
    aux = jax.tree.map(jnp.mean, auxs)
    if cfg.n_shared_experts:
        y = y + L.swiglu_apply(p["shared"], x)
    return y, aux
