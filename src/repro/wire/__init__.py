"""repro.wire — the cut-layer transport subsystem.

Turns the repo's analytic communication accounting (``repro.core.comm``,
paper Table 4) into an operational layer:

  * ``codec``     — what ships: identity / bf16 / int8 (Pallas) / top-k,
                    each with exact on-wire byte counts and STE roundtrips.
  * ``network``   — what it costs: bandwidth/RTT/jitter/straggler models
                    with ``lan`` / ``hospital_wan`` / ``cellular`` presets.
  * ``simulator`` — event-driven replay of one epoch's transfer DAG:
                    per-method wall-clock, per-client timelines,
                    straggler sensitivity; ``timeline_from_accounting``
                    expands a trained Transport's analytic per-epoch
                    accounting back into the same per-step timelines.
  * ``transport`` — the training-time hook: strategies encode/decode the
                    cut-layer tensors in-graph, meter real bytes, and
                    record per-epoch schedule signatures for the
                    analytic->timeline bridge.
"""

from repro.wire.codec import (BF16Codec, CODECS, Codec, IdentityCodec,
                              Int8Codec, TopKCodec, make_codec,
                              tree_roundtrip, tree_wire_bytes)
from repro.wire.network import SCENARIOS, NetworkModel, make_network
from repro.wire.simulator import (SimResult, Transfer, WireEvent,
                                  build_transfers, replay, simulate,
                                  straggler_sensitivity,
                                  timeline_from_accounting)
from repro.wire.transport import EpochSchedule, Transport, boundary_error

__all__ = [
    "Codec", "IdentityCodec", "BF16Codec", "Int8Codec", "TopKCodec",
    "make_codec", "tree_roundtrip", "tree_wire_bytes", "CODECS",
    "NetworkModel", "SCENARIOS", "make_network",
    "Transfer", "WireEvent", "SimResult", "build_transfers", "replay",
    "simulate", "straggler_sensitivity", "timeline_from_accounting",
    "EpochSchedule", "Transport", "boundary_error",
]
