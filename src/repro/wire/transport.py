"""Transport — the codec hook threaded through real training.

``Transport.boundary`` is a differentiable in-graph roundtrip applied to the
cut-layer activation pytree between segments (front->middle and, for NLS,
middle->tail): the server trains on exactly what it would have received over
the wire.  Lossy codecs backpropagate straight-through (see
``repro.wire.codec``).

Byte accounting happens host-side from boundary SHAPES (the roundtrip
itself never materializes a payload inside the jitted step): strategies
call ``account`` once per training step and the transport accumulates
exact on-wire and raw byte counters, cached per (adapter, batch shape).
Evaluation paths are not accounted (and not compressed) — clients score
with their own full-precision segments, matching the paper's eval
protocol.

``record_epoch`` is the analytic->timeline bridge hook: strategies hand
the transport each trained epoch's schedule signature (method kind,
interleaving schedule, per-client batch counts, per-leg byte sizes), and
``repro.wire.simulator.timeline_from_accounting`` expands those summaries
back into the exact per-step transfer DAG the event engine replays —
identical whichever engine trained, per-step or analytic accounting.
"""

from __future__ import annotations

import dataclasses
import math

import jax

from repro.wire.codec import Codec, make_codec, tree_roundtrip, \
    tree_wire_bytes


@dataclasses.dataclass(frozen=True)
class EpochSchedule:
    """One trained epoch's schedule signature (recorded by
    ``Transport.record_epoch``) — everything the wire simulator needs to
    expand the epoch's analytic accounting back into per-step transfers:
    the method kind, the client interleaving, per-client train batch
    counts, and the per-leg on-wire/raw byte sizes (``core.comm.leg_sizes``
    through this transport's codec).

    Under per-round client subsampling ``client_set`` records which
    global clients participated this round; unsampled clients carry a
    zero ``tr_counts`` entry, so the expansion naturally emits no
    transfers for them."""
    kind: str                   # "sl" | "sflv2" | "sflv3" | "sflv1"
    schedule: str               # "ac" | "am"
    tr_counts: tuple            # per-client train batch counts
    legs: dict                  # leg name -> bytes (act_fm, act_mt, ...)
    nls: bool
    client_set: tuple | None = None   # sampled global client ids (or None)


@dataclasses.dataclass
class Transport:
    codec: Codec
    #: allow the cut-layer boundary to run as ONE fused Pallas kernel when
    #: the codec supports it (``Codec.fusable``) — roundtrip and cut noise
    #: in a single pass, bit-equal to the unfused composition.  Accounting
    #: is analytic either way; set False to force the unfused reference.
    fuse: bool = True
    bytes_on_wire: float = 0.0
    bytes_raw: float = 0.0
    steps: int = 0
    epoch_log: list = dataclasses.field(default_factory=list, repr=False)
    _cache: dict = dataclasses.field(default_factory=dict, repr=False)

    def __post_init__(self):
        self.codec = make_codec(self.codec)

    # -- in-graph ------------------------------------------------------------
    def boundary(self, tree):
        """Encode+decode every leaf crossing a segment boundary.

        With ``fuse`` and a fusable codec the quantize+dequantize pair runs
        as one ``kernels/cut_fuse`` pass — bit-equal, half the HBM traffic.
        """
        if self.fuse and self.codec.fusable:
            return jax.tree.map(self.codec.fused_roundtrip, tree)
        return tree_roundtrip(self.codec, tree)

    @property
    def fused_codec(self):
        """The codec when roundtrip+noise may fuse into one kernel, else
        None — what the step builders hand ``privacy.boundary_with_key``."""
        return self.codec if self.fuse and self.codec.fusable else None

    # -- host-side accounting ------------------------------------------------
    @staticmethod
    def _shape_key(adapter, batch: dict):
        """Cache key: batch shape signature PLUS the adapter itself.

        Keying on shapes alone silently reused one adapter's boundary
        sizes for another when a single ``Transport`` was shared across
        adapters / cut points; the adapter (a frozen dataclass, hashable,
        kept alive by the cache) pins the entry to its boundary.
        """
        return (adapter,
                tuple(sorted((k, tuple(v.shape), str(v.dtype))
                             for k, v in batch.items())))

    def account(self, adapter, batch: dict, train: bool = True,
                count: int = 1):
        """Record ``count`` steps' boundary traffic (activations up + grads
        down per step).

        Cached on the (adapter, batch shape) signature, so per-step cost
        after the first call is a dict lookup.  The compiled engine
        accounts a whole epoch (or whole run) analytically in one call per
        hospital (``count=n_batches``) instead of once per host-loop step.
        """
        key = ("bytes", *self._shape_key(adapter, batch))
        if key not in self._cache:
            specs = adapter.boundary_specs(batch)
            from repro.core.partition import leaf_bytes
            wire = sum(tree_wire_bytes(self.codec, t)
                       for t in specs.values())
            raw = sum(leaf_bytes(t) for t in specs.values())
            self._cache[key] = (wire, raw)
        wire, raw = self._cache[key]
        legs = 2 if train else 1           # train: + gradient leg back
        self.bytes_on_wire += count * legs * wire
        self.bytes_raw += count * legs * raw
        self.steps += count

    def record_epoch(self, adapter, example_batch: dict, kind: str,
                     schedule: str, n_batches, client_set=None) -> None:
        """Append one trained epoch's schedule signature to ``epoch_log``.

        Called once per epoch by the SL/SFL strategies under BOTH engines
        (the stepwise per-step path and the compiled analytic path record
        identical signatures), which is what makes
        ``simulator.timeline_from_accounting`` engine-independent.
        ``client_set`` marks a participating round's sampled clients.
        """
        key = ("legs", *self._shape_key(adapter, example_batch))
        if key not in self._cache:
            from repro.core.comm import leg_sizes
            self._cache[key] = leg_sizes(adapter, example_batch,
                                         codec=self.codec)
        self.epoch_log.append(EpochSchedule(
            kind, schedule, tuple(int(n) for n in n_batches),
            self._cache[key], adapter.nls,
            None if client_set is None
            else tuple(int(c) for c in client_set)))

    @property
    def compression_ratio(self) -> float:
        if self.bytes_on_wire <= 0:
            return math.nan
        return self.bytes_raw / self.bytes_on_wire

    def reset(self):
        self.bytes_on_wire = self.bytes_raw = 0.0
        self.steps = 0
        self.epoch_log.clear()

    def summary(self) -> dict:
        return {"codec": self.codec.name, "steps": self.steps,
                "bytes_on_wire": self.bytes_on_wire,
                "bytes_raw": self.bytes_raw,
                "compression_ratio": self.compression_ratio}


def boundary_error(transport_or_codec, adapter, params, batch: dict) -> dict:
    """Reconstruction error of the codec on REAL boundary activations."""
    codec = (transport_or_codec.codec
             if isinstance(transport_or_codec, Transport)
             else make_codec(transport_or_codec))
    x = adapter.inputs(batch)
    errs = {}
    for i, seg in enumerate(adapter.seg_names[:-1]):
        x = adapter.apply_seg(seg, params[seg], x, batch, False)
        leaves = jax.tree.leaves(x)
        errs[f"{seg}->"] = [codec.error(l) for l in leaves]
        x = jax.tree.map(codec.roundtrip, x)
    return errs
