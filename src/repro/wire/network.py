"""Network models — what a byte on the wire costs in wall-clock seconds.

A ``NetworkModel`` is a symmetric per-client link to the server: fixed
bandwidth, propagation latency (half the RTT per one-way transfer),
multiplicative log-normal jitter, and a straggler mixture (a fraction of
clients whose effective bandwidth is divided by a slowdown factor —
the "one hospital is on a bad uplink" regime that dominates synchronous
SFLv3/FL rounds).

Scenario presets model the paper's deployment settings:
  * ``lan``          — hospitals co-located with the server (10 Gb/s).
  * ``hospital_wan`` — the realistic multi-site setting (100 Mb/s WAN,
                       30 ms RTT, 1 in 5 sites on a 4x slower link).
  * ``cellular``     — edge/ambulatory clients (20 Mb/s, 60 ms, heavy
                       jitter, 1 in 3 clients 8x slower).

All sampling goes through an explicit ``numpy.random.Generator`` so
simulations are reproducible and straggler ablations can reuse seeds.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    name: str
    bandwidth_bps: float          # per-client link, each direction
    rtt_s: float                  # round-trip propagation latency
    jitter: float = 0.0           # sigma of mean-one log-normal noise
    straggler_frac: float = 0.0   # fraction of clients on a slow link
    straggler_slowdown: float = 1.0   # bandwidth divisor for stragglers

    def client_multipliers(self, n_clients: int,
                           rng: np.random.Generator) -> np.ndarray:
        """Per-client transfer-time multipliers, sampled once per run."""
        mult = np.ones(n_clients)
        slow = rng.uniform(size=n_clients) < self.straggler_frac
        mult[slow] = self.straggler_slowdown
        return mult

    def transfer_time(self, nbytes: float, rng: np.random.Generator,
                      mult: float = 1.0) -> float:
        """Seconds for one one-way transfer of ``nbytes`` on this link."""
        t = self.rtt_s / 2 + mult * nbytes * 8.0 / self.bandwidth_bps
        if self.jitter > 0:
            # mean-one log-normal: E[exp(N(-s^2/2, s))] = 1
            t *= rng.lognormal(-self.jitter ** 2 / 2, self.jitter)
        return t

    def without_stragglers(self) -> "NetworkModel":
        return dataclasses.replace(self, straggler_frac=0.0,
                                   straggler_slowdown=1.0,
                                   name=f"{self.name}-nostraggler")


SCENARIOS = {
    "lan": NetworkModel("lan", bandwidth_bps=10e9, rtt_s=0.2e-3,
                        jitter=0.01),
    "hospital_wan": NetworkModel("hospital_wan", bandwidth_bps=100e6,
                                 rtt_s=30e-3, jitter=0.1,
                                 straggler_frac=0.2, straggler_slowdown=4.0),
    "cellular": NetworkModel("cellular", bandwidth_bps=20e6, rtt_s=60e-3,
                             jitter=0.3, straggler_frac=0.33,
                             straggler_slowdown=8.0),
}


def make_network(name) -> NetworkModel:
    if isinstance(name, NetworkModel):
        return name
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown network scenario {name!r} "
                       f"(one of {sorted(SCENARIOS)})") from None
