"""Cut-layer codecs — what actually crosses the client<->server wire.

A ``Codec`` turns one boundary activation leaf into its on-wire payload and
back, and — crucially for the paper's Table-4 accounting — reports the EXACT
number of bytes that payload occupies.  ``roundtrip`` is the in-graph
encode+decode used by ``repro.wire.transport`` during real training; every
lossy codec backpropagates with a straight-through estimator (the link is
quantized, client-side gradients stay full precision — the standard
deployment reading, same as ``kernels/act_compress``).

Implementations:
  * ``identity`` — ships the tensor as-is (the paper's measured regime).
  * ``bf16``     — casts to bfloat16 on the wire (2 bytes/element).
  * ``int8``     — per-row absmax int8 + one f32 scale per row, via the
                   Pallas kernel in ``repro.kernels.act_compress``.
  * ``topk``     — magnitude sparsification: top ``frac`` of elements ship
                   as (value, int32 index) pairs, the rest decode to zero.

``make_codec("topk:0.05")`` parameterizes the sparsifier fraction.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


def _ste(fn):
    """Wrap ``fn`` so the backward pass is identity (straight-through)."""
    @jax.custom_vjp
    def f(x):
        return fn(x)

    f.defvjp(lambda x: (fn(x), None), lambda _, g: (g,))
    return f


def _nelem(spec) -> int:
    return int(np.prod(spec.shape)) if spec.shape else 1


class Codec:
    """One boundary leaf -> on-wire payload -> reconstruction."""

    name: str = "codec"
    #: True when ``kernels/cut_fuse`` implements this codec's roundtrip as a
    #: single fused Pallas pass (optionally folding in cut-layer noise).
    #: ``Transport.fused_codec`` gates on it; wire_bytes stays analytic.
    fusable: bool = False

    def encode(self, x):
        """Leaf array -> pytree of payload arrays (what ships)."""
        raise NotImplementedError

    def decode(self, payload, like):
        """Payload -> reconstruction with ``like``'s shape/dtype."""
        raise NotImplementedError

    def wire_bytes(self, spec) -> int:
        """Exact on-wire bytes for a leaf of ``spec.shape``/``spec.dtype``."""
        raise NotImplementedError

    def roundtrip(self, x):
        """In-graph lossy roundtrip; lossy codecs use an STE backward."""
        raise NotImplementedError

    # -- diagnostics ---------------------------------------------------------
    def error(self, x) -> dict:
        """Reconstruction error of one leaf (host-side diagnostic)."""
        x = jnp.asarray(x)
        r = self.roundtrip(x).astype(jnp.float32)
        x = x.astype(jnp.float32)
        diff = jnp.abs(x - r)
        denom = jnp.maximum(jnp.linalg.norm(x.reshape(-1)), 1e-12)
        return {"max_abs": float(diff.max()),
                "mae": float(diff.mean()),
                "rel_l2": float(jnp.linalg.norm(diff.reshape(-1)) / denom)}

    def compression_ratio(self, spec) -> float:
        raw = _nelem(spec) * jnp.dtype(spec.dtype).itemsize
        return raw / max(self.wire_bytes(spec), 1)


class IdentityCodec(Codec):
    name = "identity"

    def encode(self, x):
        return {"x": x}

    def decode(self, payload, like):
        return payload["x"]

    def wire_bytes(self, spec) -> int:
        return _nelem(spec) * jnp.dtype(spec.dtype).itemsize

    def roundtrip(self, x):
        return x


class BF16Codec(Codec):
    name = "bf16"

    def __init__(self):
        self._rt = _ste(lambda x: x.astype(jnp.bfloat16).astype(x.dtype))

    def encode(self, x):
        return {"x": x.astype(jnp.bfloat16)}

    def decode(self, payload, like):
        return payload["x"].astype(like.dtype)

    def wire_bytes(self, spec) -> int:
        return _nelem(spec) * 2

    def roundtrip(self, x):
        if x.dtype == jnp.bfloat16:
            return x
        return self._rt(x)


class Int8Codec(Codec):
    """Per-row absmax int8 + f32 row scale (Pallas kernel, ~4x vs f32)."""

    name = "int8"
    fusable = True

    def encode(self, x):
        from repro.kernels.act_compress.ops import quantize
        q, s = quantize(x)
        return {"q": q, "scale": s}

    def decode(self, payload, like):
        from repro.kernels.act_compress.ops import dequantize
        return dequantize(payload["q"], payload["scale"], like.dtype)

    def wire_bytes(self, spec) -> int:
        rows = _nelem(spec) // (spec.shape[-1] if spec.shape else 1)
        return _nelem(spec) + 4 * max(rows, 1)

    def roundtrip(self, x):
        from repro.kernels.act_compress.ops import compress_boundary
        return compress_boundary(x)

    # -- fused path (kernels/cut_fuse) --------------------------------------
    def fused_roundtrip(self, x):
        """Quantize+dequantize in ONE Pallas pass (bit-equal to roundtrip)."""
        from repro.kernels.cut_fuse.ops import roundtrip_boundary
        return roundtrip_boundary(x)

    def fused_noise_roundtrip(self, x, zz, weights=None):
        """Roundtrip + masked pre-scaled cut noise, one fused pass.

        ``zz`` must come from ``privacy.dpsgd._leaf_noise`` — the fused
        kernel consumes that exact stream, keeping it bit-equal to
        roundtrip-then-``cut_noise_boundary``.
        """
        from repro.kernels.cut_fuse.ops import cut_noise_roundtrip
        return cut_noise_roundtrip(x, zz, weights)


class TopKCodec(Codec):
    """Magnitude top-k sparsification: ship (value, int32 index) pairs."""

    def __init__(self, frac: float = 0.1):
        assert 0.0 < frac <= 1.0
        self.frac = frac
        self.name = f"topk:{frac:g}"

        def rt(x):
            flat = x.reshape(-1)
            k = self._k(flat.shape[0])
            _, idx = jax.lax.top_k(jnp.abs(flat.astype(jnp.float32)), k)
            out = jnp.zeros_like(flat).at[idx].set(flat[idx])
            return out.reshape(x.shape)

        self._rt = _ste(rt)

    def _k(self, n: int) -> int:
        return max(1, int(math.ceil(self.frac * n)))

    def encode(self, x):
        flat = x.reshape(-1)
        k = self._k(flat.shape[0])
        _, idx = jax.lax.top_k(jnp.abs(flat.astype(jnp.float32)), k)
        return {"values": flat[idx], "indices": idx.astype(jnp.int32)}

    def decode(self, payload, like):
        flat = jnp.zeros((_nelem(like),), like.dtype)
        flat = flat.at[payload["indices"]].set(
            payload["values"].astype(like.dtype))
        return flat.reshape(like.shape)

    def wire_bytes(self, spec) -> int:
        k = self._k(_nelem(spec))
        return k * (jnp.dtype(spec.dtype).itemsize + 4)

    def roundtrip(self, x):
        return self._rt(x)


def make_codec(name) -> Codec:
    """``identity | bf16 | int8 | topk[:frac]`` (or pass a Codec through)."""
    if isinstance(name, Codec):
        return name
    if name.startswith("topk"):
        _, _, frac = name.partition(":")
        return TopKCodec(float(frac) if frac else 0.1)
    try:
        return {"identity": IdentityCodec, "bf16": BF16Codec,
                "int8": Int8Codec}[name]()
    except KeyError:
        raise KeyError(f"unknown codec {name!r} "
                       "(identity | bf16 | int8 | topk[:frac])") from None


CODECS = ("identity", "bf16", "int8", "topk:0.1")


def tree_wire_bytes(codec: Codec, tree) -> int:
    """Total on-wire bytes of a boundary pytree (specs or arrays)."""
    return int(sum(codec.wire_bytes(l) for l in jax.tree.leaves(tree)))


def tree_roundtrip(codec: Codec, tree):
    """Apply the codec roundtrip to every leaf of a boundary pytree."""
    return jax.tree.map(codec.roundtrip, tree)
