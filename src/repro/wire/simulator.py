"""Event-driven replay of one training epoch's transfers over a network.

``build_transfers`` expands a method's per-epoch communication (the SAME
legs ``repro.core.comm`` counts analytically — both sit on
``client_batch_counts``/``leg_sizes`` so the byte totals can never drift)
into a dependency DAG of one-way transfers:

  * ``sl_*``    — one long chain: the server segment is sequential, so every
                  (client, batch) hop serializes (act up, [hidden down,
                  hidden-grad up,] grad down).
  * ``sflv2_*`` — the SL chain plus an end-of-epoch client-segment
                  fed-averaging barrier (all ups, then all downs).
  * ``sflv3_*`` — batch-synchronous parallel steps: per step every active
                  client uplinks concurrently, the server averages (barrier),
                  then all gradients flow down concurrently.
  * ``fl``      — one round: model down to every client, local training
                  (no cut-layer traffic), model up.

``replay`` is the event engine: a ready-queue (heap) of transfers whose
dependencies have completed, each serialized on its client's link and
stretched by the network model's bandwidth/latency/jitter/straggler draw.
The output is a per-client timeline, the epoch wall-clock, and exact
bytes-on-wire per leg tag.

``timeline_from_accounting`` is the analytic->timeline bridge: it expands
the per-epoch schedule signatures a ``Transport`` recorded during REAL
training (``Transport.record_epoch`` + the compiled engine's
``account(count=n_batches)`` summaries) through the SAME per-epoch
expansion ``build_transfers`` uses, chained across epochs — so simulated
wall-clocks and per-tag byte breakdowns are identical whichever engine
trained, per-step or analytic accounting.

Note the simulator follows the ANALYTIC step grid: an SFLv3 client with
fewer local batches drops out of later steps, while the reference
``SplitFedV3.run_epoch`` wraps exhausted clients around (re-sending
duplicate batches).  DESIGN.md §7 records this choice — it is what keeps
the identity-codec simulation equal to paper Table 4.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict

import numpy as np

from repro.core.comm import client_batch_counts, comm_per_epoch, leg_sizes
from repro.core.schedule import SCHEDULES
from repro.wire.codec import Codec, IdentityCodec, make_codec
from repro.wire.network import NetworkModel, make_network
from repro.wire.transport import EpochSchedule, Transport

@dataclasses.dataclass(frozen=True)
class Transfer:
    """One one-way transfer in the epoch DAG."""
    id: int
    client: int
    nbytes: float
    direction: str               # "up" | "down"
    tag: str                     # comm.py breakdown key
    deps: tuple = ()


@dataclasses.dataclass(frozen=True)
class WireEvent:
    t_start: float
    t_end: float
    client: int
    direction: str
    nbytes: float
    tag: str


@dataclasses.dataclass
class SimResult:
    method: str
    codec: str
    scenario: str
    n_clients: int
    wall_clock_s: float
    bytes_on_wire: float
    bytes_raw: float
    breakdown: dict              # tag -> bytes
    per_client: dict             # client -> {busy_s, idle_frac, transfers}
    events: list

    @property
    def compression_ratio(self) -> float:
        # nothing crossed the wire (e.g. centralized): no ratio — mirror
        # Transport.compression_ratio instead of reporting bytes_raw / 1
        if self.bytes_on_wire <= 0:
            return float("nan")
        return self.bytes_raw / self.bytes_on_wire

    def timeline(self, client: int) -> list:
        return [e for e in self.events if e.client == client]


class _Dag:
    def __init__(self):
        self.transfers: list[Transfer] = []

    def add(self, client, nbytes, direction, tag, deps=()) -> int:
        tid = len(self.transfers)
        self.transfers.append(Transfer(tid, client, float(nbytes), direction,
                                       tag, tuple(deps)))
        return tid


def _train_leg_seq(dag: _Dag, client: int, legs: dict, nls: bool,
                   deps) -> int:
    """One train step's cut-layer hops for one client; returns last id.

    NLS hops: the server's hidden output travels DOWN to the client's
    tail, its gradient travels back UP — tags follow the directions
    (matching ``core.comm``'s breakdown keys).
    """
    t = dag.add(client, legs["act_fm"], "up", "train_act_up", deps)
    if nls:
        t = dag.add(client, legs["act_mt"], "down", "train_hidden_down",
                    [t])
        t = dag.add(client, legs["act_mt"], "up", "train_hidden_grad_up",
                    [t])
    return dag.add(client, legs["act_fm"], "down", "train_grad_down", [t])


def _val_leg_seq(dag: _Dag, client: int, legs: dict, nls: bool, deps) -> int:
    t = dag.add(client, legs["act_fm"], "up", "val_act_up", deps)
    if nls:
        t = dag.add(client, legs["act_mt"], "down", "val_hidden_down", [t])
    return t


def _expand_epoch(dag: _Dag, es: EpochSchedule, va_counts: list[int],
                  entry: dict) -> dict:
    """Expand ONE epoch's transfers (train legs, per-epoch validation,
    client-segment averaging) for a cut-layer method.

    ``entry`` maps each client to the transfer ids that must complete
    before its first transfer of this epoch (empty for a fresh DAG; the
    previous epoch's exits when chaining a multi-epoch run); the return
    value is this epoch's exits in the same form.  Shared verbatim by
    ``build_transfers`` (one analytic epoch) and
    ``timeline_from_accounting`` (each recorded epoch of a real run), so
    the two can never drift apart.
    """
    legs, nls = es.legs, es.nls
    n_clients = len(es.tr_counts)

    if es.kind in ("sl", "sflv2"):
        # sequential server: the whole epoch is one chain across clients
        last = tuple(sorted({d for deps in entry.values() for d in deps}))
        for c, _b in SCHEDULES[es.schedule](list(es.tr_counts)):
            last = (_train_leg_seq(dag, c, legs, nls, last),)
        for c, nb in enumerate(va_counts):
            for _ in range(nb):
                last = (_val_leg_seq(dag, c, legs, nls, last),)
        if es.kind == "sflv2":
            ups = [dag.add(c, legs["client_seg"], "up", "client_seg_avg",
                           last) for c in range(n_clients)]
            return {c: (dag.add(c, legs["client_seg"], "down",
                                "client_seg_avg", ups),)
                    for c in range(n_clients)}
        return {c: last for c in range(n_clients)}

    if es.kind in ("sflv3", "sflv1"):
        # batch-synchronous parallel steps with a server barrier per step
        barrier = {c: tuple(entry.get(c, ())) for c in range(n_clients)}
        for s in range(max(es.tr_counts, default=0)):
            active = [c for c in range(n_clients) if s < es.tr_counts[c]]
            chains = {}
            for c in active:
                t = dag.add(c, legs["act_fm"], "up", "train_act_up",
                            barrier[c])
                if nls:
                    t = dag.add(c, legs["act_mt"], "down",
                                "train_hidden_down", [t])
                    t = dag.add(c, legs["act_mt"], "up",
                                "train_hidden_grad_up", [t])
                chains[c] = t
            # server averages once every active client's gradient arrived
            ups = list(chains.values())
            for c in active:
                barrier[c] = (dag.add(c, legs["act_fm"], "down",
                                      "train_grad_down", ups),)
        if es.kind == "sflv1":
            ups = [dag.add(c, legs["client_seg"], "up", "client_seg_avg",
                           barrier[c]) for c in range(n_clients)]
            barrier = {c: (dag.add(c, legs["client_seg"], "down",
                                   "client_seg_avg", ups),)
                       for c in range(n_clients)}
        # validation: per-client chains, clients run concurrently
        exits = {}
        for c in range(n_clients):
            last = barrier[c]
            for _ in range(va_counts[c] if c < len(va_counts) else 0):
                last = (_val_leg_seq(dag, c, legs, nls, last),)
            exits[c] = last
        return exits

    raise KeyError(f"unknown method kind {es.kind!r}")


def build_transfers(method: str, adapter, example_batch: dict,
                    n_train: list[int], n_val: list[int], batch_size: int,
                    codec: Codec | None = None) -> list[Transfer]:
    """Expand one epoch of ``method`` into the transfer DAG."""
    codec = codec or IdentityCodec()
    legs = leg_sizes(adapter, example_batch, codec=codec)
    tr_counts, va_counts = client_batch_counts(n_train, n_val, batch_size)
    n_clients = len(n_train)
    dag = _Dag()

    if method == "centralized":
        return dag.transfers

    if method == "fl":
        for c in range(n_clients):
            down = dag.add(c, legs["model"], "down", "model_down")
            dag.add(c, legs["model"], "up", "model_up", [down])
        return dag.transfers

    kind, _, schedule = method.partition("_")
    es = EpochSchedule(kind, schedule or "ac", tuple(tr_counts), legs,
                       adapter.nls)
    _expand_epoch(dag, es, va_counts, {c: () for c in range(n_clients)})
    return dag.transfers


def replay(transfers: list[Transfer], network: NetworkModel,
           n_clients: int, seed: int = 0,
           multipliers: np.ndarray | None = None) -> list[WireEvent]:
    """Run the event loop: pop ready transfers, serialize per client link."""
    rng = np.random.default_rng(seed)
    if multipliers is None:
        multipliers = network.client_multipliers(n_clients, rng)
    children = defaultdict(list)
    missing = {}
    ready_at = defaultdict(float)
    for t in transfers:
        missing[t.id] = len(t.deps)
        for d in t.deps:
            children[d].append(t.id)
    heap = [(0.0, t.id) for t in transfers if not t.deps]
    heapq.heapify(heap)
    client_free = defaultdict(float)
    events: list[WireEvent | None] = [None] * len(transfers)
    done = 0
    while heap:
        t_ready, tid = heapq.heappop(heap)
        tr = transfers[tid]
        start = max(t_ready, client_free[tr.client])
        dur = network.transfer_time(tr.nbytes, rng,
                                    multipliers[tr.client])
        end = start + dur
        client_free[tr.client] = end
        events[tid] = WireEvent(start, end, tr.client, tr.direction,
                                tr.nbytes, tr.tag)
        done += 1
        for ch in children[tid]:
            ready_at[ch] = max(ready_at[ch], end)
            missing[ch] -= 1
            if missing[ch] == 0:
                heapq.heappush(heap, (ready_at[ch], ch))
    if done != len(transfers):
        raise RuntimeError("transfer DAG has a cycle or dangling dependency")
    return [e for e in events if e is not None]


def _replay_to_result(transfers, network, n_clients: int, method: str,
                      codec_name: str, bytes_raw: float, seed: int,
                      multipliers, keep_events: bool) -> SimResult:
    """Run the event engine over ``transfers`` and fold the events into a
    ``SimResult`` (wall-clock, per-tag breakdown, per-client stats)."""
    events = replay(transfers, network, n_clients, seed, multipliers)
    wall = max((e.t_end for e in events), default=0.0)
    breakdown = defaultdict(float)
    per_client = {c: {"busy_s": 0.0, "transfers": 0, "bytes": 0.0}
                  for c in range(n_clients)}
    for e in events:
        breakdown[e.tag] += e.nbytes
        pc = per_client[e.client]
        pc["busy_s"] += e.t_end - e.t_start
        pc["transfers"] += 1
        pc["bytes"] += e.nbytes
    for pc in per_client.values():
        pc["idle_frac"] = 1.0 - pc["busy_s"] / wall if wall > 0 else 0.0
    return SimResult(method=method, codec=codec_name,
                     scenario=network.name, n_clients=n_clients,
                     wall_clock_s=wall,
                     bytes_on_wire=float(sum(e.nbytes for e in events)),
                     bytes_raw=float(bytes_raw),
                     breakdown=dict(breakdown), per_client=per_client,
                     events=events if keep_events else [])


def simulate(method: str, adapter, example_batch: dict, n_train: list[int],
             n_val: list[int], batch_size: int, codec="identity",
             network="hospital_wan", seed: int = 0,
             multipliers: np.ndarray | None = None,
             keep_events: bool = True) -> SimResult:
    """One epoch of ``method`` through ``codec`` over ``network``."""
    codec = make_codec(codec)
    network = make_network(network)
    n_clients = len(n_train)
    transfers = build_transfers(method, adapter, example_batch, n_train,
                                n_val, batch_size, codec)
    raw = comm_per_epoch(method, adapter, example_batch, n_train, n_val,
                         batch_size).bytes_per_epoch
    return _replay_to_result(transfers, network, n_clients, method,
                             codec.name, raw, seed, multipliers,
                             keep_events)


def _epoch_raw_bytes(es: EpochSchedule, va_counts: list[int]) -> float:
    """Uncompressed bytes of one recorded epoch's legs (mirrors the
    ``comm_per_epoch`` terms for the cut-layer methods)."""
    tb, vb = sum(es.tr_counts), sum(va_counts)
    raw = es.legs["act_fm_raw"] * (2 * tb + vb)
    if es.nls:
        raw += es.legs["act_mt_raw"] * (2 * tb + vb)
    if es.kind in ("sflv2", "sflv1"):
        raw += 2 * es.legs["client_seg"] * len(es.tr_counts)
    return float(raw)


def timeline_from_accounting(transport: Transport, n_val=None,
                             batch_size: int | None = None,
                             network="hospital_wan", seed: int = 0,
                             multipliers: np.ndarray | None = None,
                             keep_events: bool = True) -> SimResult:
    """Replay a TRAINED transport's accounting as per-step timelines.

    Expands the per-epoch schedule signatures the transport recorded
    during training (``Transport.record_epoch`` — written identically by
    the stepwise per-step accounting and the compiled engine's analytic
    ``account(count=n_batches)`` summaries) through the same
    ``_expand_epoch`` that ``build_transfers`` uses, chaining each
    epoch's entry transfers on the previous epoch's exits, and replays
    the DAG through the event engine.  With ``n_val``/``batch_size``
    given, every epoch closes with the validation legs ``simulate``
    models; a single-epoch result is then transfer-for-transfer identical
    to ``simulate`` on the same network and seed, whichever engine
    trained.

    Like ``simulate``, the expansion follows the analytic step grid
    (DESIGN.md §7): an SFLv3 client with fewer batches drops out of later
    steps rather than wrapping around, so for uneven hospitals the
    timeline's bytes are the Table-4 analytic total, not the transport's
    wrap-around-inclusive counters.
    """
    recs = list(transport.epoch_log)
    network = make_network(network)
    if not recs:
        return SimResult(method="", codec=transport.codec.name,
                         scenario=network.name, n_clients=0,
                         wall_clock_s=0.0, bytes_on_wire=0.0,
                         bytes_raw=0.0, breakdown={}, per_client={},
                         events=[])
    n_clients = len(recs[0].tr_counts)
    va_counts = [0] * n_clients
    if n_val is not None:
        if batch_size is None:
            raise ValueError("n_val needs batch_size to derive val batch "
                             "counts")
        _, va_counts = client_batch_counts([0] * n_clients, n_val,
                                           batch_size)
    dag = _Dag()
    entry = {c: () for c in range(n_clients)}
    raw = 0.0
    for es in recs:
        if len(es.tr_counts) != n_clients:
            raise ValueError("recorded epochs disagree on client count")
        entry = _expand_epoch(dag, es, va_counts, entry)
        raw += _epoch_raw_bytes(es, va_counts)
    method = f"{recs[0].kind}_{recs[0].schedule}"
    return _replay_to_result(dag.transfers, network, n_clients, method,
                             transport.codec.name, raw, seed, multipliers,
                             keep_events)


def straggler_sensitivity(method: str, adapter, example_batch: dict,
                          n_train: list[int], n_val: list[int],
                          batch_size: int, codec="identity",
                          network="hospital_wan", seed: int = 0) -> float:
    """Epoch wall-clock ratio: with stragglers / straggler-free.

    Parallel barrier methods (SFLv3, FL) pay the slowest client every
    step/round; sequential SL only pays stragglers for their own turns.
    """
    network = make_network(network)
    with_s = simulate(method, adapter, example_batch, n_train, n_val,
                      batch_size, codec, network, seed, keep_events=False)
    clean = simulate(method, adapter, example_batch, n_train, n_val,
                     batch_size, codec, network.without_stragglers(), seed,
                     keep_events=False)
    return with_s.wall_clock_s / max(clean.wall_clock_s, 1e-12)
