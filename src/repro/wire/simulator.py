"""Event-driven replay of one training epoch's transfers over a network.

``build_transfers`` expands a method's per-epoch communication (the SAME
legs ``repro.core.comm`` counts analytically — both sit on
``client_batch_counts``/``leg_sizes`` so the byte totals can never drift)
into a dependency DAG of one-way transfers:

  * ``sl_*``    — one long chain: the server segment is sequential, so every
                  (client, batch) hop serializes (act up, [hidden down,
                  hidden-grad up,] grad down).
  * ``sflv2_*`` — the SL chain plus an end-of-epoch client-segment
                  fed-averaging barrier (all ups, then all downs).
  * ``sflv3_*`` — batch-synchronous parallel steps: per step every active
                  client uplinks concurrently, the server averages (barrier),
                  then all gradients flow down concurrently.
  * ``fl``      — one round: model down to every client, local training
                  (no cut-layer traffic), model up.

``replay`` is the event engine: a ready-queue (heap) of transfers whose
dependencies have completed, each serialized on its client's link and
stretched by the network model's bandwidth/latency/jitter/straggler draw.
The output is a per-client timeline, the epoch wall-clock, and exact
bytes-on-wire per leg tag.

Note the simulator follows the ANALYTIC step grid: an SFLv3 client with
fewer local batches drops out of later steps, while the reference
``SplitFedV3.run_epoch`` wraps exhausted clients around (re-sending
duplicate batches).  DESIGN.md §7 records this choice — it is what keeps
the identity-codec simulation equal to paper Table 4.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict

import numpy as np

from repro.core.comm import client_batch_counts, comm_per_epoch, leg_sizes
from repro.core.schedule import SCHEDULES
from repro.wire.codec import Codec, IdentityCodec, make_codec
from repro.wire.network import NetworkModel, make_network

@dataclasses.dataclass(frozen=True)
class Transfer:
    """One one-way transfer in the epoch DAG."""
    id: int
    client: int
    nbytes: float
    direction: str               # "up" | "down"
    tag: str                     # comm.py breakdown key
    deps: tuple = ()


@dataclasses.dataclass(frozen=True)
class WireEvent:
    t_start: float
    t_end: float
    client: int
    direction: str
    nbytes: float
    tag: str


@dataclasses.dataclass
class SimResult:
    method: str
    codec: str
    scenario: str
    n_clients: int
    wall_clock_s: float
    bytes_on_wire: float
    bytes_raw: float
    breakdown: dict              # tag -> bytes
    per_client: dict             # client -> {busy_s, idle_frac, transfers}
    events: list

    @property
    def compression_ratio(self) -> float:
        return self.bytes_raw / max(self.bytes_on_wire, 1.0)

    def timeline(self, client: int) -> list:
        return [e for e in self.events if e.client == client]


class _Dag:
    def __init__(self):
        self.transfers: list[Transfer] = []

    def add(self, client, nbytes, direction, tag, deps=()) -> int:
        tid = len(self.transfers)
        self.transfers.append(Transfer(tid, client, float(nbytes), direction,
                                       tag, tuple(deps)))
        return tid


def _train_leg_seq(dag: _Dag, client: int, legs: dict, nls: bool,
                   deps) -> int:
    """One train step's cut-layer hops for one client; returns last id."""
    t = dag.add(client, legs["act_fm"], "up", "train_act_up", deps)
    if nls:
        t = dag.add(client, legs["act_mt"], "down", "train_hidden_up", [t])
        t = dag.add(client, legs["act_mt"], "up", "train_hidden_grad_down",
                    [t])
    return dag.add(client, legs["act_fm"], "down", "train_grad_down", [t])


def _val_leg_seq(dag: _Dag, client: int, legs: dict, nls: bool, deps) -> int:
    t = dag.add(client, legs["act_fm"], "up", "val_act_up", deps)
    if nls:
        t = dag.add(client, legs["act_mt"], "down", "val_hidden_up", [t])
    return t


def build_transfers(method: str, adapter, example_batch: dict,
                    n_train: list[int], n_val: list[int], batch_size: int,
                    codec: Codec | None = None) -> list[Transfer]:
    """Expand one epoch of ``method`` into the transfer DAG."""
    codec = codec or IdentityCodec()
    legs = leg_sizes(adapter, example_batch, codec=codec)
    tr_counts, va_counts = client_batch_counts(n_train, n_val, batch_size)
    n_clients = len(n_train)
    nls = adapter.nls
    dag = _Dag()

    if method == "centralized":
        return dag.transfers

    if method == "fl":
        for c in range(n_clients):
            down = dag.add(c, legs["model"], "down", "model_down")
            dag.add(c, legs["model"], "up", "model_up", [down])
        return dag.transfers

    kind, _, schedule = method.partition("_")
    schedule = schedule or "ac"

    if kind in ("sl", "sflv2"):
        # sequential server: the whole epoch is one chain across clients
        last = ()
        for c, _b in SCHEDULES[schedule](tr_counts):
            last = [_train_leg_seq(dag, c, legs, nls, last)]
        for c, nb in enumerate(va_counts):
            for _ in range(nb):
                last = [_val_leg_seq(dag, c, legs, nls, last)]
        if kind == "sflv2":
            ups = [dag.add(c, legs["client_seg"], "up", "client_seg_avg",
                           last) for c in range(n_clients)]
            for c in range(n_clients):
                dag.add(c, legs["client_seg"], "down", "client_seg_avg", ups)
        return dag.transfers

    if kind in ("sflv3", "sflv1"):
        # batch-synchronous parallel steps with a server barrier per step
        barrier = {c: () for c in range(n_clients)}
        for s in range(max(tr_counts, default=0)):
            active = [c for c in range(n_clients) if s < tr_counts[c]]
            chains = {}
            for c in active:
                t = dag.add(c, legs["act_fm"], "up", "train_act_up",
                            barrier[c])
                if nls:
                    t = dag.add(c, legs["act_mt"], "down", "train_hidden_up",
                                [t])
                    t = dag.add(c, legs["act_mt"], "up",
                                "train_hidden_grad_down", [t])
                chains[c] = t
            # server averages once every active client's gradient arrived
            ups = list(chains.values())
            for c in active:
                barrier[c] = (dag.add(c, legs["act_fm"], "down",
                                      "train_grad_down", ups),)
        if kind == "sflv1":
            ups = [dag.add(c, legs["client_seg"], "up", "client_seg_avg",
                           barrier[c]) for c in range(n_clients)]
            for c in range(n_clients):
                barrier[c] = (dag.add(c, legs["client_seg"], "down",
                                      "client_seg_avg", ups),)
        # validation: per-client chains, clients run concurrently
        for c, nb in enumerate(va_counts):
            last = barrier[c]
            for _ in range(nb):
                last = [_val_leg_seq(dag, c, legs, nls, last)]
        return dag.transfers

    raise KeyError(f"unknown method {method!r}")


def replay(transfers: list[Transfer], network: NetworkModel,
           n_clients: int, seed: int = 0,
           multipliers: np.ndarray | None = None) -> list[WireEvent]:
    """Run the event loop: pop ready transfers, serialize per client link."""
    rng = np.random.default_rng(seed)
    if multipliers is None:
        multipliers = network.client_multipliers(n_clients, rng)
    children = defaultdict(list)
    missing = {}
    ready_at = defaultdict(float)
    for t in transfers:
        missing[t.id] = len(t.deps)
        for d in t.deps:
            children[d].append(t.id)
    heap = [(0.0, t.id) for t in transfers if not t.deps]
    heapq.heapify(heap)
    client_free = defaultdict(float)
    events: list[WireEvent | None] = [None] * len(transfers)
    done = 0
    while heap:
        t_ready, tid = heapq.heappop(heap)
        tr = transfers[tid]
        start = max(t_ready, client_free[tr.client])
        dur = network.transfer_time(tr.nbytes, rng,
                                    multipliers[tr.client])
        end = start + dur
        client_free[tr.client] = end
        events[tid] = WireEvent(start, end, tr.client, tr.direction,
                                tr.nbytes, tr.tag)
        done += 1
        for ch in children[tid]:
            ready_at[ch] = max(ready_at[ch], end)
            missing[ch] -= 1
            if missing[ch] == 0:
                heapq.heappush(heap, (ready_at[ch], ch))
    if done != len(transfers):
        raise RuntimeError("transfer DAG has a cycle or dangling dependency")
    return [e for e in events if e is not None]


def simulate(method: str, adapter, example_batch: dict, n_train: list[int],
             n_val: list[int], batch_size: int, codec="identity",
             network="hospital_wan", seed: int = 0,
             multipliers: np.ndarray | None = None,
             keep_events: bool = True) -> SimResult:
    """One epoch of ``method`` through ``codec`` over ``network``."""
    codec = make_codec(codec)
    network = make_network(network)
    n_clients = len(n_train)
    transfers = build_transfers(method, adapter, example_batch, n_train,
                                n_val, batch_size, codec)
    events = replay(transfers, network, n_clients, seed, multipliers)
    wall = max((e.t_end for e in events), default=0.0)
    breakdown = defaultdict(float)
    per_client = {c: {"busy_s": 0.0, "transfers": 0, "bytes": 0.0}
                  for c in range(n_clients)}
    for e in events:
        breakdown[e.tag] += e.nbytes
        pc = per_client[e.client]
        pc["busy_s"] += e.t_end - e.t_start
        pc["transfers"] += 1
        pc["bytes"] += e.nbytes
    for pc in per_client.values():
        pc["idle_frac"] = 1.0 - pc["busy_s"] / wall if wall > 0 else 0.0
    raw = comm_per_epoch(method, adapter, example_batch, n_train, n_val,
                         batch_size).bytes_per_epoch
    return SimResult(method=method, codec=codec.name, scenario=network.name,
                     n_clients=n_clients, wall_clock_s=wall,
                     bytes_on_wire=float(sum(e.nbytes for e in events)),
                     bytes_raw=float(raw), breakdown=dict(breakdown),
                     per_client=per_client,
                     events=events if keep_events else [])


def straggler_sensitivity(method: str, adapter, example_batch: dict,
                          n_train: list[int], n_val: list[int],
                          batch_size: int, codec="identity",
                          network="hospital_wan", seed: int = 0) -> float:
    """Epoch wall-clock ratio: with stragglers / straggler-free.

    Parallel barrier methods (SFLv3, FL) pay the slowest client every
    step/round; sequential SL only pays stragglers for their own turns.
    """
    network = make_network(network)
    with_s = simulate(method, adapter, example_batch, n_train, n_val,
                      batch_size, codec, network, seed, keep_events=False)
    clean = simulate(method, adapter, example_batch, n_train, n_val,
                     batch_size, codec, network.without_stragglers(), seed,
                     keep_events=False)
    return with_s.wall_clock_s / max(clean.wall_clock_s, 1e-12)
