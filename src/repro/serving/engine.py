"""Serving steps: batched prefill and single-token decode with KV/SSM caches.

``decode_32k`` / ``long_500k`` lower ``decode_step`` — ONE new token against
a cache of seq_len — and ``prefill_32k`` lowers ``prefill_step`` (cache
filled in one pass, last-position logits returned), per the assignment.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp


def make_prefill_step(model, max_len: int, cache_dtype=jnp.bfloat16):
    def prefill_step(params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        cache = model.cache_init(b, max_len, dtype=cache_dtype)
        logits, cache, _ = model.apply(
            params, tokens, cache=cache,
            frontend_emb=batch.get("frontend_emb"), use_pallas=False)
        return logits[:, -1, :], cache
    return prefill_step


def make_decode_step(model):
    def decode_step(params, cache, tokens, positions):
        """tokens: (B, 1); positions: (B, 1) absolute positions."""
        logits, cache, _ = model.apply(params, tokens, positions=positions,
                                       cache=cache)
        return logits[:, -1, :], cache
    return decode_step


def greedy_generate(model, params, prompt, max_new: int, max_len: int,
                    cache_dtype=jnp.float32):
    """Simple autoregressive loop used by the serving example."""
    b, s = prompt.shape
    cache = model.cache_init(b, max_len, dtype=cache_dtype)
    logits, cache, _ = model.apply(params, prompt, cache=cache)
    decode = jax.jit(make_decode_step(model))
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(max_new - 1):
        pos = jnp.full((b, 1), s + i, jnp.int32)
        lg, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
