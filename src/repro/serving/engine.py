"""Serving steps: batched prefill and single-token decode with KV/SSM caches.

``decode_32k`` / ``long_500k`` lower ``decode_step`` — ONE new token against
a cache of seq_len — and ``prefill_32k`` lowers ``prefill_step`` (cache
filled in one pass, last-position logits returned), per the assignment.
"""

from __future__ import annotations

import weakref

import jax
import jax.numpy as jnp


def make_prefill_step(model, max_len: int, cache_dtype=jnp.bfloat16):
    def prefill_step(params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        cache = model.cache_init(b, max_len, dtype=cache_dtype)
        logits, cache, _ = model.apply(
            params, tokens, cache=cache,
            frontend_emb=batch.get("frontend_emb"), use_pallas=False)
        return logits[:, -1, :], cache
    return prefill_step


def make_decode_step(model):
    def decode_step(params, cache, tokens, positions):
        """tokens: (B, 1); positions: (B, 1) absolute positions."""
        logits, cache, _ = model.apply(params, tokens, positions=positions,
                                       cache=cache)
        return logits[:, -1, :], cache
    return decode_step


# one jitted decode step per live model: jitting inside greedy_generate
# rebuilt the traced callable every call, so repeated generations paid a
# fresh trace each time.  Models are frozen dataclasses (hashable and
# weakref-able), so a WeakKeyDictionary keeps one compiled step per model
# without pinning dead models; unhashable/unweakrefable models fall back
# to an uncached jit.
_DECODE_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def jitted_decode_step(model):
    """The per-model decode step, jitted ONCE (donating the running cache
    buffer — each decode step consumes the old cache and returns the
    updated one, so the device reuses its pages instead of holding both)."""
    try:
        fn = _DECODE_CACHE.get(model)
        if fn is None:
            fn = jax.jit(make_decode_step(model), donate_argnums=(1,))
            _DECODE_CACHE[model] = fn
        return fn
    except TypeError:                        # unhashable/unweakrefable model
        return jax.jit(make_decode_step(model), donate_argnums=(1,))


def greedy_generate(model, params, prompt, max_new: int, max_len: int,
                    cache_dtype=jnp.bfloat16):
    """Simple autoregressive loop used by the serving example.

    ``cache_dtype`` defaults to bf16, matching ``make_prefill_step`` —
    the fp32 default used to silently double the decode cache footprint
    relative to a prefilled cache.
    """
    b, s = prompt.shape
    cache = model.cache_init(b, max_len, dtype=cache_dtype)
    logits, cache, _ = model.apply(params, prompt, cache=cache)
    decode = jitted_decode_step(model)
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(max_new - 1):
        pos = jnp.full((b, 1), s + i, jnp.int32)
        lg, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(lg, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
