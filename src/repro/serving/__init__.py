"""repro.serving — the deploy side of the train→deploy loop.

Four pieces close the loop over the five training strategies
(DESIGN.md §15):

  * ``export``  — ``Strategy.export(state) -> ServableModel``: the full
                  deployable model (split halves stitched at the cut),
                  round-trippable via ``save_servable``/``load_servable``.
  * ``scorer``  — ``BucketScorer``: pre-lowered padded-bucket AOT scoring
                  programs (zero fresh compiles in steady state) behind a
                  hot-swappable ``ModelSlot``.
  * ``batcher`` — ``RequestBatcher``/``ScreeningService``: queue that
                  coalesces single-image requests into the largest ready
                  bucket under a max-wait, with backpressure, per-request
                  latency accounting, and ``repro.obs`` trace lanes.
  * ``engine``  — the sequence-model decode path (KV-cache prefill +
                  jit-once greedy decode), independent of the CNN service.
"""

from repro.serving.batcher import (Backpressure, RequestBatcher,
                                   ScreeningService)
from repro.serving.export import ServableModel, load_servable, save_servable
from repro.serving.scorer import (DEFAULT_BUCKETS, BucketScorer, ModelSlot,
                                  PRECISIONS)

__all__ = ["ServableModel", "save_servable", "load_servable",
           "BucketScorer", "ModelSlot", "DEFAULT_BUCKETS", "PRECISIONS",
           "RequestBatcher", "ScreeningService", "Backpressure"]
