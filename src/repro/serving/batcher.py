"""Request-batching front end — single images in, coalesced buckets out.

``RequestBatcher`` runs one dispatcher thread over a bounded queue.
Callers submit ONE image at a time (the screening-clinic arrival model);
the dispatcher drains whatever is waiting and scores it as one padded
bucket, waiting at most ``max_wait_s`` after the first arrival for the
batch to fill toward the largest ladder bucket.  The policy:

  * queue non-empty and ``max_wait_s`` expired for the oldest request,
    OR the waiting count reached the largest bucket -> dispatch now with
    the largest ready bucket (``BucketScorer`` pads the remainder).
  * bounded queue (``max_queue``) -> ``submit`` raises ``Backpressure``
    instead of growing latency unboundedly; callers shed or retry.

Per-request latency accounting rides a ``repro.obs`` Tracer when one is
attached: each request becomes a queue-wait span on its own arrival
track plus batch-level pad / dispatch / readback spans on the dispatcher
track (``PID_SERVING`` lane), so service traces merge with engine and
wire lanes via ``merge_events`` into one Chrome-trace file.

``ScreeningService`` is the user-facing bundle: scorer + batcher +
``swap()`` pass-through, with sync ``score_one`` (submit and block) and
``stats()`` (p50/p99 over completed requests).
"""

from __future__ import annotations

import collections
import threading
import time

import numpy as np

from repro.obs.trace import PID_SERVING, Tracer
from repro.serving.scorer import BucketScorer


class Backpressure(RuntimeError):
    """Raised by ``submit`` when the request queue is full."""


class _Request:
    __slots__ = ("example", "t_submit", "done", "score", "info", "lat")

    def __init__(self, example: dict):
        self.example = example
        self.t_submit = time.perf_counter()
        self.done = threading.Event()
        self.score = None
        self.info = None
        self.lat = None   # dict of phase latencies (seconds), set on completion


class RequestBatcher:
    """Coalesce single-image submissions into padded-bucket dispatches."""

    def __init__(self, scorer: BucketScorer, max_wait_s: float = 0.002,
                 max_queue: int = 256, tracer: Tracer | None = None):
        self.scorer = scorer
        self.max_wait_s = float(max_wait_s)
        self.max_queue = int(max_queue)
        self.tracer = tracer
        self._q: collections.deque[_Request] = collections.deque()
        self._cv = threading.Condition()
        self._stop = False
        self._completed: list[dict] = []
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="serving-batcher")
        self._thread.start()

    # -- client side -----------------------------------------------------------
    def submit(self, example: dict) -> _Request:
        """Enqueue one example (dict of per-sample arrays, no batch axis);
        returns a handle whose ``done`` event fires when scored.  Raises
        ``Backpressure`` when ``max_queue`` requests are already waiting."""
        req = _Request(example)
        with self._cv:
            if self._stop:
                raise RuntimeError("batcher is closed")
            if len(self._q) >= self.max_queue:
                raise Backpressure(
                    f"serving queue full ({self.max_queue} waiting)")
            self._q.append(req)
            self._cv.notify()
        return req

    def score_one(self, example: dict, timeout: float = 30.0) -> float:
        """Submit and block until scored (the sync client path)."""
        req = self.submit(example)
        if not req.done.wait(timeout):
            raise TimeoutError("scoring request timed out")
        return float(req.score)

    # -- dispatcher ------------------------------------------------------------
    def _take_batch(self) -> list[_Request]:
        """Block until the dispatch policy fires; returns [] on close."""
        b_max = self.scorer.buckets[-1]
        with self._cv:
            while True:
                if self._q:
                    oldest = self._q[0].t_submit
                    if (len(self._q) >= b_max
                            or time.perf_counter() - oldest
                            >= self.max_wait_s):
                        take = min(len(self._q), b_max)
                        return [self._q.popleft() for _ in range(take)]
                    # wake when the oldest request's wait expires
                    self._cv.wait(self.max_wait_s
                                  - (time.perf_counter() - oldest))
                elif self._stop:
                    return []
                else:
                    self._cv.wait()

    def _run(self):
        while True:
            reqs = self._take_batch()
            if not reqs:
                return
            t_drain = time.perf_counter()
            batch = {k: np.stack([np.asarray(r.example[k]) for r in reqs])
                     for k in reqs[0].example}
            scores, info = self.scorer.score(batch)
            t_done = time.perf_counter()
            for i, r in enumerate(reqs):
                r.score = float(scores[i])
                r.info = info
                r.lat = {
                    "total_s": t_done - r.t_submit,
                    "queue_s": t_drain - r.t_submit,
                    "pad_s": info["pad_s"],
                    "dispatch_s": info["dispatch_s"],
                    "readback_s": info["readback_s"],
                    "bucket": info["buckets"][0] if info["buckets"] else 0,
                    "batch_n": len(reqs),
                    "version": info["version"],
                }
                self._completed.append(r.lat)
                r.done.set()
            self._trace(reqs, t_drain, t_done, info)

    def _trace(self, reqs, t_drain, t_done, info):
        tr = self.tracer
        if tr is None:
            return
        # batch-level phase spans on the dispatcher track (tid 1)
        d0 = tr.now() - (t_done - t_drain)
        t = d0
        for phase in ("pad", "dispatch", "readback"):
            tr.event(phase, t, t + info[f"{phase}_s"], tid=1,
                     n=len(reqs), bucket=info["buckets"],
                     version=info["version"])
            t += info[f"{phase}_s"]
        # per-request queue-wait spans on an arrivals track (tid 2)
        for r in reqs:
            q0 = d0 - r.lat["queue_s"]
            tr.event("queue_wait", q0, d0, tid=2,
                     total_ms=round(r.lat["total_s"] * 1e3, 3))

    # -- stats / lifecycle -----------------------------------------------------
    def stats(self) -> dict:
        """p50/p99 latency split over every completed request so far."""
        done = self._completed
        if not done:
            return {"n": 0}
        out = {"n": len(done),
               "batch_n_mean": float(np.mean([d["batch_n"] for d in done]))}
        for k in ("total_s", "queue_s", "dispatch_s"):
            v = np.asarray([d[k] for d in done]) * 1e3
            out[f"{k[:-2]}_p50_ms"] = float(np.percentile(v, 50))
            out[f"{k[:-2]}_p99_ms"] = float(np.percentile(v, 99))
        return out

    def close(self):
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout=10)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class ScreeningService:
    """Scorer + batcher + hot-swap, bundled: the deployable service.

    ``tracer`` (optional) collects the per-request span lanes; pass
    ``Tracer(pid=PID_SERVING)`` or let the service build one with
    ``trace=True``.
    """

    def __init__(self, servable, image_shape=None, example=None,
                 buckets=None, precision: str = "fp32",
                 max_wait_s: float = 0.002, max_queue: int = 256,
                 trace: bool = False, tracer: Tracer | None = None):
        from repro.serving.scorer import DEFAULT_BUCKETS
        if tracer is None and trace:
            tracer = Tracer(pid=PID_SERVING)
        self.tracer = tracer
        self.scorer = BucketScorer(
            servable, example=example, image_shape=image_shape,
            buckets=buckets or DEFAULT_BUCKETS, precision=precision)
        self.batcher = RequestBatcher(self.scorer, max_wait_s=max_wait_s,
                                      max_queue=max_queue, tracer=tracer)

    def submit(self, example: dict):
        return self.batcher.submit(example)

    def score_one(self, example: dict, timeout: float = 30.0) -> float:
        return self.batcher.score_one(example, timeout=timeout)

    def score_batch(self, batch: dict):
        """Direct batch path (bypasses the queue — offline eval)."""
        return self.scorer.score(batch)

    def swap(self, params_or_servable) -> int:
        return self.scorer.swap(params_or_servable)

    @property
    def version(self) -> int:
        return self.scorer.version

    def stats(self) -> dict:
        return self.batcher.stats()

    def trace_events(self) -> list:
        if self.tracer is None:
            return []
        from repro.obs.trace import _meta
        return _meta(self.tracer.pid, "screening service", 1, "dispatcher") \
            + _meta(self.tracer.pid, "screening service", 2, "arrivals")[1:] \
            + list(self.tracer.events)

    def close(self):
        self.batcher.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


__all__ = ["RequestBatcher", "ScreeningService", "Backpressure"]
