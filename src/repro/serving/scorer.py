"""Compiled batched scoring core — the deploy side's hot path.

``BucketScorer`` pre-lowers ONE scoring program per bucket of a fixed
batch-size ladder at construction time (AOT ``jit.lower(...).compile()``),
so no request shape ever triggers a fresh compile in steady state: a
request batch of n images is padded (repeating the last row — the repo's
standard partial-batch idiom; padded scores are sliced off) up to the
smallest bucket >= n, and batches beyond the largest bucket chunk through
it.  The padded input buffer is donated — it is rebuilt per dispatch, so
the device reuses its pages instead of allocating fresh ones.

``n_compiles`` / ``n_dispatches`` count program builds and invocations;
the serving benchmark asserts ``n_compiles`` stays FROZEN across the
entire timed load (the zero-fresh-compiles acceptance gate).

``ModelSlot`` is the zero-downtime hot-swap handle: a single reference
``(version, params)`` swapped atomically (one attribute assignment under
the GIL), so an in-flight dispatch that read the slot keeps scoring a
CONSISTENT param tree — old or new, never half of each — while the next
dispatch picks up the swap.  Swapping never touches the compiled
programs: they are specialized to param shapes/dtypes only, which a
federated round's update preserves (checked at swap time).

``precision="bf16"`` casts params and float inputs to bfloat16 inside the
compiled program (scores stay fp32 via ``scores_from_output``) — the
serving counterpart of the training engine's bf16 compute mode.  fp32
(default) is bit-exact to ``Strategy.scores``.
"""

from __future__ import annotations

import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.export import ServableModel

DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32, 64)
PRECISIONS = ("fp32", "bf16")


class ModelSlot:
    """In-flight-safe, versioned parameter handle.

    Readers call ``get()`` ONCE per dispatch and hold the returned
    ``(version, params)`` pair for the dispatch's whole lifetime;
    ``swap`` replaces the pair atomically, so a reader can never observe
    a torn (half-old / half-new) tree.
    """

    def __init__(self, params, version: int = 0):
        self._ref = (version, params)

    @property
    def version(self) -> int:
        return self._ref[0]

    def get(self):
        return self._ref

    def swap(self, params) -> int:
        """Install a new param tree behind the handle; returns the new
        version.  The structure must match the incumbent's (a federated
        round updates values, never shapes) — a mismatch would silently
        invalidate the pre-lowered programs, so it raises instead."""
        version, old = self._ref
        if (jax.tree.structure(params) != jax.tree.structure(old)):
            raise ValueError("swap() param tree structure differs from the "
                             "serving model's — export/strategy mismatch")
        for new_l, old_l in zip(jax.tree.leaves(params),
                                jax.tree.leaves(old)):
            if (jnp.shape(new_l) != jnp.shape(old_l)):
                raise ValueError("swap() param leaf shapes differ from the "
                                 "serving model's")
        self._ref = (version + 1, params)
        return version + 1


class BucketScorer:
    """Padded-bucket AOT scoring programs behind a hot-swappable slot.

    ``servable``: the exported model (adapter + params).  ``example``:
    a dict of ONE example's arrays (no batch axis) fixing the request
    shapes the programs are lowered at; defaults to zeros shaped from
    ``image_shape`` for the CNN families.
    """

    def __init__(self, servable: ServableModel, example: dict | None = None,
                 image_shape: tuple | None = None,
                 buckets=DEFAULT_BUCKETS, precision: str = "fp32",
                 donate_input: bool = True):
        if precision not in PRECISIONS:
            raise ValueError(f"unknown precision {precision!r} "
                             f"(one of {PRECISIONS})")
        if example is None:
            if image_shape is None:
                raise ValueError("BucketScorer needs an example dict or an "
                                 "image_shape")
            example = {"image": np.zeros(image_shape, np.float32)}
        self.servable = servable
        self.slot = ModelSlot(servable.params)
        self.example = {k: np.asarray(v) for k, v in example.items()}
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        if not self.buckets or self.buckets[0] < 1:
            raise ValueError("buckets must be positive ints")
        self.precision = precision
        self.donate_input = donate_input
        self.n_compiles = 0
        self.n_dispatches = 0
        self._progs = {}
        fs = servable.adapter.full_scores

        def score_fn(params, batch):
            if precision == "bf16":
                cast = lambda t: jax.tree.map(       # noqa: E731
                    lambda l: l.astype(jnp.bfloat16)
                    if jnp.issubdtype(jnp.asarray(l).dtype, jnp.floating)
                    else l, t)
                params, batch = cast(params), cast(batch)
            return fs(params, batch)

        self._score_fn = score_fn
        for b in self.buckets:
            self._compile_bucket(b)

    # -- compilation -----------------------------------------------------------
    def _batch_spec(self, b: int):
        return {k: jax.ShapeDtypeStruct((b, *v.shape), v.dtype)
                for k, v in self.example.items()}

    def _compile_bucket(self, b: int):
        donate = (1,) if self.donate_input else ()
        jitted = jax.jit(self._score_fn, donate_argnums=donate)
        param_spec = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(jnp.shape(l),
                                           jnp.asarray(l).dtype),
            self.servable.params)
        with warnings.catch_warnings():
            # backends without donation support (CPU) warn at compile
            # time; the donation is then simply a no-op
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            self._progs[b] = jitted.lower(param_spec,
                                          self._batch_spec(b)).compile()
        self.n_compiles += 1

    # -- hot swap --------------------------------------------------------------
    def swap(self, params_or_servable) -> int:
        """Install a new export behind the live programs (zero downtime —
        in-flight dispatches finish on the old tree)."""
        params = (params_or_servable.params
                  if isinstance(params_or_servable, ServableModel)
                  else params_or_servable)
        return self.slot.swap(jax.tree.map(jnp.asarray, params))

    @property
    def version(self) -> int:
        return self.slot.version

    # -- scoring ---------------------------------------------------------------
    def bucket_for(self, n: int) -> int:
        """Smallest ladder bucket >= n (the largest bucket for chunking)."""
        for b in self.buckets:
            if b >= n:
                return b
        return self.buckets[-1]

    def _pad_to(self, batch: dict, b: int) -> dict:
        out = {}
        for k, v in batch.items():
            v = np.asarray(v)
            if len(v) < b:
                v = np.concatenate([v, np.repeat(v[-1:], b - len(v),
                                                 axis=0)])
            out[k] = np.ascontiguousarray(v)
        return out

    def score(self, batch: dict):
        """Score a request batch; returns ``(scores, info)`` with scores
        ``(n,)`` float32 and ``info`` carrying the model version plus the
        pad / dispatch / readback wall-clock split and the bucket(s)
        used.  Never compiles: every shape routes through the pre-lowered
        ladder (oversize batches chunk through the largest bucket)."""
        n = len(next(iter(batch.values())))
        if n == 0:
            return np.zeros((0,), np.float32), {
                "version": self.slot.version, "buckets": [], "pad_s": 0.0,
                "dispatch_s": 0.0, "readback_s": 0.0, "n_dispatch": 0}
        version, params = self.slot.get()
        b_max = self.buckets[-1]
        info = {"version": version, "buckets": [], "pad_s": 0.0,
                "dispatch_s": 0.0, "readback_s": 0.0, "n_dispatch": 0}
        outs = []
        for s in range(0, n, b_max):
            chunk = {k: np.asarray(v)[s:s + b_max]
                     for k, v in batch.items()}
            m = min(b_max, n - s)
            b = self.bucket_for(m)
            t0 = time.perf_counter()
            padded = self._pad_to(chunk, b)
            t1 = time.perf_counter()
            out = self._progs[b](params, padded)
            out.block_until_ready()
            t2 = time.perf_counter()
            outs.append(np.asarray(out).reshape(b, -1)[:m, 0]
                        if np.asarray(out).ndim > 1
                        else np.asarray(out)[:m])
            t3 = time.perf_counter()
            self.n_dispatches += 1
            info["buckets"].append(b)
            info["pad_s"] += t1 - t0
            info["dispatch_s"] += t2 - t1
            info["readback_s"] += t3 - t2
            info["n_dispatch"] += 1
        return (np.concatenate(outs).astype(np.float32, copy=False)
                .reshape(-1), info)


__all__ = ["ModelSlot", "BucketScorer", "DEFAULT_BUCKETS", "PRECISIONS"]
