"""Checkpoint export — the train side of the train→deploy loop.

``Strategy.export(state, client_idx)`` materializes the full deployable
model from ANY of the five training strategies as a ``ServableModel``:

  * centralized / FL: the one global param tree (every hospital scores
    with the same model, so ``client_idx`` is ignored).
  * SL / SFLv2 / SFLv3 / SFLv1: hospital ``client_idx``'s client
    segment(s) stitched with the shared server segment at the cut layer —
    exactly the composition ``Strategy.params_for_eval`` uses, so the
    per-client-head (U-shaped/NLS) variants resolve per DESIGN.md: a
    sample scored by the export passes through that hospital's own
    front (and tail) and the shared middle.

``ServableModel.scores`` replicates ``Strategy.scores`` VERBATIM — same
pad-to-grid, same nested-vmap program, same singleton-hospital stacking —
so the exported model's scores are bit-identical to the training-side
eval (asserted per strategy x precision in tests/test_serving_service.py).

``save_servable`` / ``load_servable`` round-trip the export through a
single msgpack file (params flattened exactly like ``train.checkpoint``
plus a JSON-safe meta record); loading needs only the adapter — the
param structure is recovered from ``adapter.init``'s shape tree.
"""

from __future__ import annotations

import dataclasses
import json
import os
from functools import partial

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.core.partition import SplitAdapter, stack_trees


@dataclasses.dataclass
class ServableModel:
    """A deployable full model: adapter + stitched param tree + metadata.

    ``shared`` mirrors ``Strategy.shared_eval_params`` — it selects the
    exact vmap axes ``Strategy.scores`` uses, which is what makes the
    export's scores bit-identical to the training-side eval.
    """
    adapter: SplitAdapter
    params: dict
    shared: bool
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def family(self) -> str:
        return self.adapter.name

    def _scores_fn(self):
        if not hasattr(self, "_scores_jit"):
            fs = self.adapter.full_scores
            in_p = None if self.shared else 0
            self._scores_jit = jax.jit(jax.vmap(
                lambda p, d: jax.vmap(partial(fs, p))(d),
                in_axes=(in_p, 0)))
        return self._scores_jit

    def scores(self, data: dict, batch_size: int = 60) -> np.ndarray:
        """Per-sample scores for every sample of ``data`` — the same
        pad-and-slice grid as ``Strategy.scores`` (bit-exact to it)."""
        n = len(next(iter(data.values())))
        if n == 0:
            return np.zeros((0,))
        params = self.params
        if not self.shared:
            params = stack_trees([params])
        bs = min(batch_size, n)
        nb = -(-n // bs)
        L = nb * bs
        stacked = {}
        for k, v in data.items():
            v = np.asarray(v)
            if len(v) != L:
                v = np.concatenate([v, np.repeat(v[-1:], L - len(v),
                                                 axis=0)])
            stacked[k] = v.reshape(1, nb, bs, *v.shape[1:])
        out = np.asarray(self._scores_fn()(params, stacked))
        return out.reshape(L, *out.shape[3:])[:n]


# ---------------------------------------------------------------------------
# checkpoint round-trip
# ---------------------------------------------------------------------------

def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_servable(path: str, servable: ServableModel) -> None:
    """One msgpack file: JSON-safe meta + flattened param leaves."""
    flat, _ = _flatten(servable.params)
    payload = {
        "__meta__": json.dumps({**servable.meta,
                                "family": servable.family,
                                "shared": bool(servable.shared)}),
        "params": {k: {"dtype": str(v.dtype), "shape": list(v.shape),
                       "data": v.tobytes()} for k, v in flat.items()},
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))


def load_servable(path: str, adapter: SplitAdapter) -> ServableModel:
    """Restore an export; the param structure comes from ``adapter.init``
    (segments the export lacks — e.g. no tail — are dropped to match)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    meta = json.loads(payload["__meta__"])
    recs = payload["params"]
    like = jax.eval_shape(adapter.init, jax.random.key(0))
    flat_spec, treedef = jax.tree_util.tree_flatten_with_path(like)
    flat_like = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                 for p in path) for path, _ in flat_spec]
    missing = [k for k in flat_like if k not in recs]
    if missing:
        raise ValueError(f"checkpoint {path} lacks params for {missing[:3]}"
                         f"{'...' if len(missing) > 3 else ''}")
    leaves = []
    for key, (_, spec) in zip(flat_like, flat_spec):
        rec = recs[key]
        if tuple(rec["shape"]) != tuple(spec.shape):
            raise ValueError(
                f"checkpoint {path} param {key!r} has shape "
                f"{tuple(rec['shape'])}, adapter expects "
                f"{tuple(spec.shape)} — architecture mismatch")
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"]))
        leaves.append(jnp.asarray(arr.reshape(rec["shape"])))
    params = jax.tree.unflatten(treedef, leaves)
    shared = bool(meta.pop("shared"))
    meta.pop("family", None)
    return ServableModel(adapter=adapter, params=params, shared=shared,
                         meta=meta)


__all__ = ["ServableModel", "save_servable", "load_servable"]
