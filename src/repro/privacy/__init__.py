"""repro.privacy — the paper's missing title axis, made measurable.

The comparison is of *privacy-preserving* methods, yet AUROC/bytes/seconds
never touch privacy.  This subsystem enforces it and measures it:

  * ``dpsgd``      — PrivacyConfig + per-example clip/noise gradients
                     (fused Pallas kernel in ``kernels/dp_clip``) and
                     cut-layer activation noise.
  * ``accountant`` — RDP/moments accountant composing (eps, delta) across
                     FL rounds, SL client turns and SplitFed epochs,
                     reported PER HOSPITAL.
  * ``leakage``    — No-Peek cut-layer metrics: distance correlation and
                     linear reconstruction / label probes, evaluated on
                     exactly what crosses the ``repro.wire`` transport.
  * ``secagg``     — pairwise-mask secure aggregation for FedAvg with
                     exact modular cancellation and metered mask-exchange
                     bytes.

Entry points: ``make_strategy(..., privacy=PrivacyConfig(...))``,
``benchmarks/privacy_sweep.py``, ``examples/private_splitfed.py``.
"""

from repro.privacy.accountant import (DEFAULT_ORDERS, RDPAccountant,
                                      epoch_steps, epsilon, rdp_to_eps,
                                      rdp_sampled_gaussian)
from repro.privacy.dpsgd import (PrivacyConfig, cut_noise_boundary,
                                 dp_value_and_grad, per_example_grads)
from repro.privacy.leakage import (distance_correlation, label_probe_auc,
                                   measure_leakage, reconstruction_probe,
                                   smashed_activations)
from repro.privacy.secagg import SecAgg

__all__ = [
    "PrivacyConfig", "dp_value_and_grad", "per_example_grads",
    "cut_noise_boundary",
    "RDPAccountant", "epsilon", "epoch_steps", "rdp_sampled_gaussian",
    "rdp_to_eps", "DEFAULT_ORDERS",
    "distance_correlation", "measure_leakage", "reconstruction_probe",
    "label_probe_auc", "smashed_activations",
    "SecAgg",
]
