"""DP-SGD — per-example clipping + Gaussian noising of gradients.

``PrivacyConfig`` is the single knob threaded through ``make_strategy`` and
the jitted step builders in ``core/strategies/base.py``.  Two mechanisms,
composable (see DESIGN.md §8 for the threat model):

  * **DP-SGD** (``clip_norm``/``noise_multiplier``): per-example gradients
    via ``jax.vmap(jax.grad(...))`` over a singleton batch axis, clipped and
    batch-reduced by the fused Pallas kernel in ``kernels/dp_clip`` (the
    clipped per-example tree never hits HBM), then ``N(0, (sigma*C)^2)``
    noise on the SUM before the 1/B mean.  Guarantees are per-hospital and
    composed by ``privacy.accountant``.
  * **Cut-layer noise** (``cut_noise_std``): Gaussian noise added to the
    smashed activations at every segment boundary — Li et al.'s mitigation
    for the No-Peek server-inference risk, measured by ``privacy.leakage``.

With ``noise_multiplier=0`` and ``clip_norm=inf`` the DP path reduces to
exact per-example-mean gradients — numerically the non-private step
(asserted in ``tests/test_privacy.py``).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro import optim as O


@dataclasses.dataclass(frozen=True)
class PrivacyConfig:
    """Privacy mechanisms for one training run.

    noise_multiplier : sigma of the DP-SGD Gaussian mechanism (noise std is
                       ``sigma * clip_norm`` on the clipped gradient SUM).
    clip_norm        : per-example L2 clip C; ``inf`` disables clipping.
    delta            : target delta for the (eps, delta) report.
    cut_noise_std    : std of Gaussian noise on cut-layer activations
                       (SL/SFL families only; 0 disables).
    secagg           : pairwise-mask secure aggregation for FedAvg uploads.
    seed             : base seed for all privacy randomness.
    use_kernel       : fused Pallas clip kernel vs the jnp reference.
    force_dp         : run the DP-SGD machinery even with neutral
                       parameters (noise 0 / clip inf) — used to assert the
                       DP path reduces to the non-private step exactly.
    """
    noise_multiplier: float = 0.0
    clip_norm: float = math.inf
    delta: float = 1e-5
    cut_noise_std: float = 0.0
    secagg: bool = False
    seed: int = 0
    use_kernel: bool = True
    force_dp: bool = False

    def __post_init__(self):
        if self.noise_multiplier > 0 and not math.isfinite(self.clip_norm):
            raise ValueError(
                "noise_multiplier > 0 with clip_norm=inf has unbounded "
                "sensitivity — no (eps, delta) statement exists; set a "
                "finite clip_norm")

    @property
    def dp_enabled(self) -> bool:
        return (self.noise_multiplier > 0 or math.isfinite(self.clip_norm)
                or self.force_dp)

    @property
    def any_enabled(self) -> bool:
        return self.dp_enabled or self.cut_noise_std > 0 or self.secagg


def keyed(loss_fn):
    """Lift ``loss_fn(params, batch)`` to the keyed 3-arg signature."""
    return lambda params, batch, key: loss_fn(params, batch)


def step_key(base_key, index):
    """Per-step key as ``fold_in(base_key, index)``.

    ``index`` may be a Python int (the stepwise engine's running counter)
    or a traced uint32 scan index (the compiled engine folds the reserved
    counter value in INSIDE the epoch scan) — both derive bit-identical
    keys, which is what keeps DP/cut-noise draws equal across engines and
    lets accountant step counts be derived analytically per epoch.
    """
    return jax.random.fold_in(base_key, index)


def _expand_batch(batch):
    """(B, ...) batch dict -> per-example batches of size 1 along axis 0."""
    return jax.tree.map(lambda v: v[:, None], batch)


def per_example_grads(loss_fn, params, batch, keys, has_aux=False):
    """vmap'd (loss, grad) over singleton sub-batches.

    ``loss_fn(params, batch, key) -> scalar`` must be a per-batch MEAN, so
    a size-1 batch yields that example's own loss/gradient; ``keys`` is a
    (B,)-keyed array giving each example independent noise (cut-layer
    noise draws must be iid across examples).  Returns ((B,) losses, grad
    tree with leading batch axis); with ``has_aux`` the loss_fn returns
    ``(loss, aux)`` and the result is ``((losses, aux_stacked), grads)``
    — the telemetry taps ride per-example aux out of the vmap without
    touching the gradient computation.
    """
    def one(b, k):
        return jax.value_and_grad(loss_fn, has_aux=has_aux)(params, b, k)
    return jax.vmap(one)(_expand_batch(batch), keys)


def example_keys(key, b: int):
    """(B,) per-example keys as ``fold_in(key, example_idx)``.

    fold_in (unlike ``jax.random.split``, whose draws depend on the split
    COUNT) gives example ``j`` a key independent of the batch LENGTH — so
    a pad-and-mask padded batch draws the same noise for its real examples
    as the stepwise short batch does, which is what makes DP under
    ``drop_remainder=False`` engine-independent.
    """
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.arange(b, dtype=jnp.uint32))


def dp_value_and_grad(loss_fn, cfg: PrivacyConfig, has_aux=False,
                      with_norms=False):
    """DP analogue of ``jax.value_and_grad``.

    ``loss_fn(params, batch, key) -> scalar`` (use ``keyed`` to lift a
    keyless loss).  Returns ``fn(params, batch, key, weights=None) ->
    (mean loss, noisy clipped mean grad)``: ``(sum_b clip(g_b) +
    sigma*C*z) / B`` with ``z ~ N(0, I)`` — the standard Abadi et al.
    DP-SGD estimator.

    ``weights`` is an optional (B,) 0/1 validity mask (the compiled
    engine's pad-and-mask rows under ``drop_remainder=False``): weighted
    per-example clipping scales each per-example gradient by its weight
    BEFORE the clip — a zero-weight padded example clips to exactly zero
    contribution — and the mean divides by the REAL example count
    ``sum(weights)``, so the estimator equals the stepwise short-batch
    step bit-for-bit (noise included: the noise key and the summed-grad
    shape do not depend on padding).

    Telemetry hooks (both leave the estimator untouched): ``has_aux``
    makes loss_fn return ``(loss, aux)`` with per-example aux stacked
    along axis 0, and ``with_norms`` exposes the (B,) per-example
    pre-clip gradient norms the clip kernel computes anyway.  With either
    set the returned fn yields ``(loss, grad, extras)`` where extras may
    hold ``"aux"`` and/or ``"norms"``.
    """
    if cfg.use_kernel:
        from repro.kernels.dp_clip.ops import clip_accumulate
        clip_fn = lambda g: clip_accumulate(g, float(cfg.clip_norm))
    else:
        from repro.kernels.dp_clip.ref import clip_accumulate_ref
        clip_fn = lambda g: clip_accumulate_ref(g, float(cfg.clip_norm))

    # PrivacyConfig rejects noise > 0 with clip inf (unbounded sensitivity)
    noise_std = float(cfg.noise_multiplier) * float(cfg.clip_norm) \
        if cfg.noise_multiplier > 0 else 0.0

    def fn(params, batch, key, weights=None):
        b = jax.tree.leaves(batch)[0].shape[0]
        ex_key, noise_key = jax.random.split(key)
        out = per_example_grads(loss_fn, params, batch,
                                example_keys(ex_key, b), has_aux=has_aux)
        (losses, aux), grads = out if has_aux else ((out[0], None), out[1])
        if weights is None:
            denom, loss = b, losses.mean()
        else:
            w = weights.astype(jnp.float32)
            grads = jax.tree.map(
                lambda g: g * w.reshape((b,) + (1,) * (g.ndim - 1)), grads)
            denom = jnp.maximum(w.sum(), 1.0)
            loss = (losses * w).sum() / denom
        summed, norms = clip_fn(grads)
        summed = O.tree_gaussian_noise(summed, noise_key, noise_std)
        grad = jax.tree.map(lambda s, p: (s / denom).astype(p.dtype),
                            summed, params)
        if not (has_aux or with_norms):
            return loss, grad
        extras = {}
        if has_aux:
            extras["aux"] = aux
        if with_norms:
            extras["norms"] = norms
        return loss, grad, extras

    return fn


def _leaf_noise(l, lk, std: float):
    """Per-example std-scaled Gaussian draws for one payload leaf.

    Example ``j`` draws ``normal(fold_in(lk, j), l.shape[1:])`` — like
    ``example_keys``, a real example's draw depends on its position, never
    on the batch LENGTH, so a pad-and-mask padded remainder batch noises
    its real rows exactly as the stepwise short batch does.

    This is the ONE noise subgraph both the fused and unfused cut-noise
    paths consume, scaling included: ``normal`` ends in its own constant
    multiply (erfinv output times sqrt(2)), and XLA's algebraic simplifier
    merges adjacent constant multiplies program-dependently — keeping the
    draw and the ``std`` scale in one shared function (and pinning the
    scale's rounding, see ``pin_product``) is what makes the two paths
    bit-equal regardless of the surrounding program.
    """
    from repro.kernels.cut_fuse.cut_fuse import pin_product
    b = l.shape[0]
    ks = jax.vmap(lambda i: jax.random.fold_in(lk, i))(
        jnp.arange(b, dtype=jnp.uint32))
    z0 = jax.vmap(
        lambda k: jax.random.normal(k, l.shape[1:], jnp.float32))(ks)
    return pin_product(float(std) * z0, z0)


def cut_noise_boundary(base_boundary, cut_noise_std: float, codec=None):
    """Wrap a transport boundary fn with additive Gaussian cut-layer noise.

    Returns ``fn(tree, key, weights=None)``; noise rides AFTER the codec
    roundtrip — the client adds it to exactly what ships, so the server
    (and the leakage probe) only ever sees the noised payload.

    Draws are PER-EXAMPLE: leaf ``l`` of the payload gets key
    ``fold_in(key, leaf_idx)`` and per-example draws via ``_leaf_noise``
    (this is what lets the compiled engine keep ``drop_remainder=False``
    with cut-layer noise and no DP).  ``weights`` (optional (B,) 0/1
    validity) zeroes the noise on padded rows so the shipped payload stays
    clean there.

    With a fusable ``codec`` (``Int8Codec``), the roundtrip AND the masked
    noise add run as ONE Pallas kernel per leaf
    (``kernels/cut_fuse``): the kernel consumes the identical
    ``_leaf_noise`` stream and applies ``zz * weight`` in the same f32 op
    order, so fused == unfused bitwise.  ``base_boundary`` is skipped —
    the fused op IS the codec roundtrip; analytic byte accounting is
    untouched.
    """
    std = float(cut_noise_std)
    fused_rt = getattr(codec, "fused_noise_roundtrip", None) \
        if codec is not None else None

    def fn(tree, key, weights=None):
        from repro.kernels.cut_fuse.cut_fuse import pin_product
        if fused_rt is None and base_boundary is not None:
            tree = base_boundary(tree)
        leaves, treedef = jax.tree.flatten(tree)
        noised = []
        for li, l in enumerate(leaves):
            lk = jax.random.fold_in(key, jnp.uint32(li))
            zz = _leaf_noise(l, lk, std)
            if fused_rt is not None:
                noised.append(fused_rt(l, zz, weights))
                continue
            b = l.shape[0]
            # pin each multiply's own f32 rounding: XLA:CPU may otherwise
            # contract the codec-dequant or mask multiply into the final
            # add as an FMA, and whether it does depends on the
            # surrounding program — the fused kernel pins the same
            # intermediates, keeping the two paths bit-equal everywhere
            if weights is not None:
                zw = pin_product(
                    zz * weights.astype(jnp.float32).reshape(
                        (b,) + (1,) * (l.ndim - 1)), zz)
            else:
                zw = zz
            noised.append(pin_product(l, zz.astype(l.dtype))
                          + zw.astype(l.dtype))
        return jax.tree.unflatten(treedef, noised)

    return fn


def boundary_with_key(base_boundary, cfg: PrivacyConfig, key, weights=None,
                      codec=None):
    """Bind a step key into a ``boundary(tree)`` hook for full_loss.

    Each boundary crossing folds a fresh trace-time counter into ``key`` so
    front->middle and middle->tail draws are independent.  ``weights``
    (per-example pad-mask, compiled engine only) masks the noise on padded
    rows; a fusable ``codec`` routes roundtrip+noise through the single
    fused kernel — see ``cut_noise_boundary``.
    """
    if cfg is None or cfg.cut_noise_std <= 0:
        return base_boundary
    noised = cut_noise_boundary(base_boundary, cfg.cut_noise_std, codec)
    crossing = [0]

    def fn(tree):
        k = jax.random.fold_in(key, crossing[0])
        crossing[0] += 1
        return noised(tree, k, weights)

    return fn
