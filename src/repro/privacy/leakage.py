"""Cut-layer leakage metrics — what a curious server learns from smashed
activations.

Vepakomma et al.'s *No Peek* frames the central split-learning risk as
server-side inference from the cut-layer tensors.  Two complementary
measurements, both evaluated on EXACTLY what crosses the wire (the
activations are pulled through the ``repro.wire`` transport boundary and
any cut-layer DP noise before measuring):

  * ``distance_correlation`` — nonparametric statistical dependence between
    smashed activations and raw inputs / labels (0 = independent, 1 =
    deterministically related); the No-Peek leakage measure.
  * reconstruction / label probes — ridge-regression attacks fit on a train
    split of the smashed activations and scored on a held-out split:
    input-reconstruction R^2 and label-probe AUROC.  These are the
    cheapest honest-but-curious attacks; stronger attackers only do better,
    so probe numbers are leakage LOWER bounds.

All numpy, evaluation-time only (never in the jitted path).
"""

from __future__ import annotations

import jax
import numpy as np


def _pairwise_dists(x: np.ndarray) -> np.ndarray:
    """(n, d) -> (n, n) Euclidean distance matrix via the gram trick."""
    sq = (x * x).sum(axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    return np.sqrt(np.maximum(d2, 0.0))


def _double_center(d: np.ndarray) -> np.ndarray:
    return (d - d.mean(axis=0, keepdims=True) - d.mean(axis=1, keepdims=True)
            + d.mean())


def distance_correlation(x, y) -> float:
    """Szekely's distance correlation between row-paired samples."""
    x = np.asarray(x, np.float64).reshape(len(x), -1)
    y = np.asarray(y, np.float64).reshape(len(y), -1)
    if len(x) != len(y):
        raise ValueError(f"paired samples required: {len(x)} vs {len(y)}")
    a = _double_center(_pairwise_dists(x))
    b = _double_center(_pairwise_dists(y))
    dcov2 = (a * b).mean()
    dvar_x, dvar_y = (a * a).mean(), (b * b).mean()
    denom = np.sqrt(dvar_x * dvar_y)
    if denom <= 0:
        return 0.0
    return float(np.sqrt(max(dcov2, 0.0) / denom))


def _ridge_fit(z: np.ndarray, t: np.ndarray, l2: float) -> np.ndarray:
    """Closed-form ridge: (n, d) acts, (n, k) targets -> (d+1, k) weights."""
    z1 = np.concatenate([z, np.ones((len(z), 1))], axis=1)
    gram = z1.T @ z1 + l2 * np.eye(z1.shape[1])
    return np.linalg.solve(gram, z1.T @ t)


def _ridge_predict(z: np.ndarray, w: np.ndarray) -> np.ndarray:
    return np.concatenate([z, np.ones((len(z), 1))], axis=1) @ w


def reconstruction_probe(acts, inputs, l2: float = 1e-2,
                         train_frac: float = 0.7, seed: int = 0) -> dict:
    """Linear input-reconstruction attack; returns held-out R^2 and MSE."""
    z = np.asarray(acts, np.float64).reshape(len(acts), -1)
    t = np.asarray(inputs, np.float64).reshape(len(inputs), -1)
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(z))
    n_tr = max(int(train_frac * len(z)), 1)
    tr, te = idx[:n_tr], idx[n_tr:]
    if len(te) == 0:
        tr, te = idx, idx
    w = _ridge_fit(z[tr], t[tr], l2)
    pred = _ridge_predict(z[te], w)
    resid = ((pred - t[te]) ** 2).mean()
    var = t[te].var()
    r2 = 1.0 - resid / max(var, 1e-12)
    return {"r2": float(max(r2, 0.0)), "mse": float(resid),
            "baseline_var": float(var)}


def label_probe_auc(acts, labels, l2: float = 1e-2,
                    train_frac: float = 0.7, seed: int = 0) -> float:
    """Held-out AUROC of a linear probe from smashed activations to labels."""
    from repro.train.metrics import auroc
    z = np.asarray(acts, np.float64).reshape(len(acts), -1)
    t = np.asarray(labels, np.float64).reshape(-1, 1)
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(z))
    n_tr = max(int(train_frac * len(z)), 1)
    tr, te = idx[:n_tr], idx[n_tr:]
    if len(te) == 0:
        tr, te = idx, idx
    w = _ridge_fit(z[tr], t[tr], l2)
    return auroc(t[te].ravel() > 0.5, _ridge_predict(z[te], w).ravel())


def smashed_activations(adapter, params, batch, transport=None,
                        privacy=None, seed: int = 0) -> np.ndarray:
    """front(batch) as seen by the server: after the wire codec and any
    cut-layer DP noise.  Returns a flattened (B, D) float32 matrix."""
    x = adapter.apply_seg("front", params["front"], adapter.inputs(batch),
                          batch, False)
    if transport is not None:
        x = transport.boundary(x)
    if privacy is not None and privacy.cut_noise_std > 0:
        from repro.privacy.dpsgd import cut_noise_boundary
        fn = cut_noise_boundary(None, privacy.cut_noise_std)
        x = fn(x, jax.random.key(seed))
    leaves = [np.asarray(l, np.float32) for l in jax.tree.leaves(x)]
    b = leaves[0].shape[0]
    return np.concatenate([l.reshape(b, -1) for l in leaves], axis=1)


def measure_leakage(adapter, params, batch, transport=None, privacy=None,
                    seed: int = 0) -> dict:
    """All cut-layer leakage metrics on one evaluation batch."""
    z = smashed_activations(adapter, params, batch, transport, privacy, seed)
    inputs = np.asarray(adapter.inputs(batch))
    labels = np.asarray(batch["label"]) if "label" in batch else None
    out = {
        "dcor_input": distance_correlation(z, inputs),
        "probe": reconstruction_probe(z, inputs, seed=seed),
    }
    if labels is not None and len(np.unique(labels > 0.5)) == 2:
        out["dcor_label"] = distance_correlation(z, labels.reshape(-1, 1))
        out["label_probe_auc"] = label_probe_auc(z, labels, seed=seed)
    return out
