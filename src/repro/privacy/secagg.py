"""Pairwise-mask secure aggregation for FedAvg (Bonawitz et al., simulated).

Each pair of hospitals (i, j) derives a shared mask from a pairwise seed
(standing in for the X25519 key agreement of the real protocol); client i
ADDS the mask to its update, client j SUBTRACTS it, so the server-side SUM
telescopes to the true aggregate while every individual upload is
uniformly-random garbage.

Arithmetic is fixed-point modulo 2^32 — exactly like the deployed protocol —
so mask cancellation is EXACT (no float cancellation error); the only loss
is the fixed-point quantization of the update itself (<= 2^-frac_bits per
element, default 2^-16).  Weighted FedAvg folds the normalized data-size
weight in client-side (weights are public metadata), keeping the server a
pure modular adder.

Wire costs ride on ``repro.wire``'s byte accounting: the masked payload is
metered with ``tree_wire_bytes`` (uint32 ships like f32 — secagg hides the
update but does not compress it) plus the pairwise handshake bytes
(2x32 B keys per client up, the keyset broadcast down, and one encrypted
share per ordered pair relayed through the server).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.wire.codec import IdentityCodec, tree_wire_bytes

KEY_BYTES = 32          # one X25519 public key
SHARE_BYTES = 120       # one encrypted masked-seed share (seed + MAC + iv)


@dataclasses.dataclass
class SecAgg:
    """One aggregation group of ``n_clients`` hospitals."""
    n_clients: int
    seed: int = 0
    frac_bits: int = 16
    bytes_on_wire: float = 0.0
    rounds: int = 0

    def __post_init__(self):
        self._codec = IdentityCodec()
        self._scale = float(2 ** self.frac_bits)

    # -- fixed point ---------------------------------------------------------
    def _quantize(self, tree):
        return jax.tree.map(
            lambda x: np.round(np.asarray(x, np.float64)
                               * self._scale).astype(np.int64)
            .astype(np.uint32), tree)

    def _dequantize_sum(self, tree):
        """uint32 modular sum -> float (centered signed interpretation)."""
        def deq(x):
            signed = x.astype(np.int64)
            signed = np.where(signed >= 2 ** 31, signed - 2 ** 32, signed)
            return (signed / self._scale).astype(np.float32)
        return jax.tree.map(deq, tree)

    def _pair_masks(self, i: int, j: int, tree):
        """Shared uint32 mask stream for the unordered pair {i, j}."""
        lo, hi = min(i, j), max(i, j)
        rng = np.random.default_rng((self.seed, self.rounds, lo, hi))
        return jax.tree.map(
            lambda x: rng.integers(0, 2 ** 32, size=np.shape(x),
                                   dtype=np.uint32), tree)

    # -- protocol ------------------------------------------------------------
    def mask_update(self, client: int, tree, weight: float):
        """Client-side: fixed-point encode ``weight * tree`` + pair masks."""
        q = self._quantize(jax.tree.map(
            lambda x: np.asarray(x, np.float64) * weight, tree))
        for other in range(self.n_clients):
            if other == client:
                continue
            m = self._pair_masks(client, other, tree)
            sign = 1 if client < other else -1
            q = jax.tree.map(
                lambda a, b: (a.astype(np.int64)
                              + sign * b.astype(np.int64)) % (2 ** 32),
                q, m)
            q = jax.tree.map(lambda a: a.astype(np.uint32), q)
        return q

    def aggregate(self, masked_trees):
        """Server-side: modular sum; masks telescope away."""
        total = masked_trees[0]
        for t in masked_trees[1:]:
            total = jax.tree.map(
                lambda a, b: ((a.astype(np.int64) + b.astype(np.int64))
                              % (2 ** 32)).astype(np.uint32), total, t)
        return self._dequantize_sum(total)

    def aggregate_weighted(self, trees, weights):
        """Full round: mask every client's update, sum, meter the bytes.

        ``weights`` are data sizes; normalization happens client-side so the
        modular sum is directly the weighted mean.
        """
        wsum = float(sum(weights))
        masked = [self.mask_update(i, t, w / wsum)
                  for i, (t, w) in enumerate(zip(trees, weights))]
        self._account(trees[0])
        self.rounds += 1
        return self.aggregate(masked)

    # -- byte metering (repro.wire accounting) -------------------------------
    def handshake_bytes(self) -> int:
        n = self.n_clients
        keys_up = n * 2 * KEY_BYTES
        keys_down = n * (n - 1) * 2 * KEY_BYTES      # keyset broadcast
        shares = n * (n - 1) * 2 * SHARE_BYTES       # relay: up + down legs
        return keys_up + keys_down + shares

    def _account(self, example_tree):
        payload = tree_wire_bytes(
            self._codec, jax.tree.map(lambda x: np.asarray(x, np.float32),
                                      example_tree))
        self.bytes_on_wire += (self.n_clients * payload
                               + self.handshake_bytes())

    def summary(self) -> dict:
        return {"n_clients": self.n_clients, "rounds": self.rounds,
                "bytes_on_wire": self.bytes_on_wire,
                "handshake_bytes_per_round": self.handshake_bytes(),
                "frac_bits": self.frac_bits}
