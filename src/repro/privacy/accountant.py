"""RDP (moments) accountant for the subsampled Gaussian mechanism.

Tracks Renyi-DP at a grid of integer orders and converts to an
(eps, delta) statement.  For Poisson-style subsampling at rate ``q`` with
noise multiplier ``sigma``, the per-step RDP at integer order ``alpha`` is
(Mironov et al. 2019, eq. for the Sampled Gaussian Mechanism):

    RDP(alpha) = log( sum_{k=0..alpha} C(alpha,k) (1-q)^(alpha-k) q^k
                      * exp((k^2 - k) / (2 sigma^2)) ) / (alpha - 1)

computed in log-space.  RDP composes additively across steps — which is
exactly what lets one accountant span FL rounds, SL client turns and
SplitFed epochs: every mechanism application on a hospital's data is one
``step(q, n)`` call, whatever the schedule interleaving looks like
(DESIGN.md §8 records the per-schedule counts).

Guarantees are PER HOSPITAL: hospital i's sampling rate is
``batch_size / n_i`` against its own dataset, so unequal data volumes (the
paper's 3772-vs-880 split) yield unequal epsilons at the same sigma.
"""

from __future__ import annotations

import math


DEFAULT_ORDERS = tuple(range(2, 65)) + (80, 96, 128, 192, 256)


def _log_comb(n: int, k: int) -> float:
    return (math.lgamma(n + 1) - math.lgamma(k + 1)
            - math.lgamma(n - k + 1))


def rdp_sampled_gaussian(q: float, sigma: float, order: int) -> float:
    """One step's RDP at integer ``order`` for sampling rate ``q``."""
    if order < 2 or order != int(order):
        raise ValueError(f"integer orders >= 2 only, got {order}")
    if sigma <= 0:
        return math.inf
    if q <= 0:
        return 0.0
    if q >= 1.0:
        return order / (2 * sigma * sigma)       # plain Gaussian mechanism
    log_terms = []
    for k in range(order + 1):
        log_terms.append(_log_comb(order, k)
                         + (order - k) * math.log1p(-q)
                         + (k * math.log(q) if k else 0.0)
                         + (k * k - k) / (2 * sigma * sigma))
    m = max(log_terms)
    lse = m + math.log(sum(math.exp(t - m) for t in log_terms))
    return max(lse, 0.0) / (order - 1)


def rdp_to_eps(rdp: dict[int, float], delta: float) -> tuple[float, int]:
    """min over orders of the classic RDP->(eps, delta) conversion."""
    best, best_order = math.inf, 0
    for order, r in rdp.items():
        if not math.isfinite(r):
            continue
        eps = r + math.log(1.0 / delta) / (order - 1)
        if eps < best:
            best, best_order = eps, order
    return best, best_order


class RDPAccountant:
    """Composes subsampled-Gaussian steps; reports (eps, delta)."""

    def __init__(self, noise_multiplier: float, delta: float = 1e-5,
                 orders=DEFAULT_ORDERS):
        self.noise_multiplier = float(noise_multiplier)
        self.delta = float(delta)
        self.orders = tuple(orders)
        self._rdp = {o: 0.0 for o in self.orders}
        self.steps = 0
        self._cache: dict[float, dict] = {}

    def step(self, q: float, count: int = 1):
        """Record ``count`` mechanism applications at sampling rate ``q``.

        ``q <= 0`` (never sampled) is a no-op: the data never entered the
        mechanism, so no privacy is spent."""
        if count <= 0 or q <= 0:
            return
        if q not in self._cache:
            self._cache[q] = {o: rdp_sampled_gaussian(
                q, self.noise_multiplier, o) for o in self.orders}
        per = self._cache[q]
        for o in self.orders:
            self._rdp[o] += count * per[o]
        self.steps += count

    def epsilon(self) -> tuple[float, int]:
        if self.steps == 0:
            return 0.0, 0
        return rdp_to_eps(self._rdp, self.delta)

    def summary(self) -> dict:
        eps, order = self.epsilon()
        return {"epsilon": eps, "delta": self.delta,
                "noise_multiplier": self.noise_multiplier,
                "steps": self.steps, "opt_order": order}


def epsilon(noise_multiplier: float, q: float, steps: int,
            delta: float = 1e-5) -> float:
    """One-shot (eps at delta) for ``steps`` compositions at rate ``q``."""
    acct = RDPAccountant(noise_multiplier, delta)
    acct.step(q, steps)
    return acct.epsilon()[0]


def epoch_steps(method: str, n_train: list[int], batch_size: int) -> list:
    """Per-hospital (q, steps) for ONE epoch of each training schedule.

    FL local epochs and both SL schedules visit every client batch exactly
    once per epoch (AC vs AM only permutes the interleaving — composition
    is order-invariant).  Batch-synchronous SFLv3/v1 wrap short clients
    around so every client is sampled ``max_b`` times per epoch.
    """
    counts = [max(n // batch_size, 1) for n in n_train]
    qs = [min(batch_size / max(n, 1), 1.0) for n in n_train]
    if method.startswith(("sflv3", "sflv1")):
        steps = [max(counts)] * len(n_train)
    else:                                   # fl / centralized / sl / sflv2
        steps = counts
    return list(zip(qs, steps))
