"""In-program telemetry — metric taps riding the compiled engine's scans.

The compiled engine (PRs 3-5) lowers whole multi-epoch runs into ONE XLA
program, which made training fast and *opaque*: the program emits final
params and a loss stack, nothing else.  ``Telemetry`` is the spec of what
else to observe; the taps are computed INSIDE the jitted step functions
from intermediates the step already has (gradients, updates, cut-layer
payloads, per-example clip norms) and ride the existing scans as extra
outputs:

  * no host callbacks — a telemetry-enabled ``Strategy.run`` is still ONE
    dispatch, the metrics simply come back stacked ``[E, ...]`` next to
    the loss stack;
  * pure observation — telemetry consumes no PRNG draws and reorders no
    computation, so telemetry-on params are BIT-identical to
    telemetry-off (asserted in tests/test_obs.py);
  * placement-aware — per-hospital metric stacks ride the "hosp" mesh
    like the losses and the host-side reducers below un-pad phantom
    hospitals.

Metric taps (each gated by a ``Telemetry`` flag AND by availability —
cut-layer stats only exist for the SL/SFL family, clip fractions only
under DP-SGD):

  ``loss``           per-round x per-hospital mean train loss
  ``grad_norm``      global L2 of the step gradient
  ``update_norm``    global L2 of the optimizer update actually applied
  ``update_cosine``  FL only: cosine of each hospital's round update to
                     the weighted-mean (FedAvg) update
  ``cut_mean/std/absmax``  moments of the cut-layer payload exactly as it
                     crosses the wire (post-codec, post-noise)
  ``clip_frac``      DP-SGD: fraction of examples whose per-example grad
                     was clipped
  ``epsilon``        per-round cumulative RDP epsilon per hospital
                     (composed host-side from the same counts the real
                     accountant uses)

The host-side reducers (``rounds_client_major`` / ``rounds_scheduled`` /
``rounds_sync``) fold the per-step stacks into one ``RoundTelemetry`` per
training round, shared by both engines: the stepwise oracle collects the
same taps per step and reduces through the same code, which is what makes
telemetry itself engine-independent (parity-tested).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Telemetry:
    """Spec of in-program metric taps for one observed run.

    Flags select taps; a tap that does not apply to the strategy at hand
    (cut stats without a cut layer, clip fractions without DP) is simply
    absent from the output.  ``Telemetry()`` enables everything.
    """
    loss: bool = True
    norms: bool = True            # grad_norm + update_norm
    update_cosine: bool = True    # FL: per-round cosine-to-mean update
    cut_stats: bool = True        # SL/SFL: cut payload mean/std/absmax
    clip_fraction: bool = True    # DP-SGD: fraction of clipped examples
    epsilon: bool = True          # per-round RDP eps per hospital

    @property
    def enabled(self) -> bool:
        return (self.loss or self.norms or self.update_cosine
                or self.cut_stats or self.clip_fraction or self.epsilon)

    def step_keys(self, dp: bool, cut: bool) -> tuple[str, ...]:
        """Static key set of the per-step metric dict a step function
        emits — fixed at trace time so the scan carries a constant
        pytree structure."""
        keys = []
        if self.norms:
            keys += ["grad_norm", "update_norm"]
        if self.cut_stats and cut:
            keys += ["cut_mean", "cut_std", "cut_absmax"]
        if self.clip_fraction and dp:
            keys += ["clip_frac"]
        return tuple(keys)


def as_telemetry(observe) -> Telemetry | None:
    """Normalize an ``observe=`` argument: None/False off, True -> all
    taps, or a ``Telemetry`` instance."""
    if observe is None or observe is False:
        return None
    if observe is True:
        return Telemetry()
    if not isinstance(observe, Telemetry):
        raise TypeError(f"observe must be a Telemetry, got {observe!r}")
    return observe if observe.enabled else None


# ---------------------------------------------------------------------------
# in-graph taps (traceable; called from the jitted step functions)
# ---------------------------------------------------------------------------

def global_norm(tree):
    """Global L2 norm over every leaf of a gradient/update pytree."""
    import jax
    import jax.numpy as jnp
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
             for l in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def stacked_global_norm(tree):
    """Per-row global L2 norms of a stacked (leading hospital axis)
    pytree -> ``[C]``."""
    import jax
    return jax.vmap(global_norm)(tree)


def payload_moments(tree, weights=None):
    """(mean, mean-of-squares, absmax) of a cut-layer payload pytree.

    Every leaf carries a leading batch axis; ``weights`` is an optional
    (B,) 0/1 validity mask (pad-and-mask rows) — masked examples
    contribute to no moment.  Equal per-example element counts (true for
    every adapter payload) make the weighted mean of per-example means
    the batch mean.
    """
    import jax
    import jax.numpy as jnp
    leaves = jax.tree.leaves(tree)
    b = leaves[0].shape[0]
    flat = jnp.concatenate(
        [l.reshape(b, -1).astype(jnp.float32) for l in leaves], axis=1)
    if weights is None:
        return (flat.mean(), jnp.square(flat).mean(),
                jnp.abs(flat).max())
    w = weights.astype(jnp.float32)
    denom = jnp.maximum(w.sum(), 1.0) * flat.shape[1]
    wcol = w[:, None]
    mean = (flat * wcol).sum() / denom
    meansq = (jnp.square(flat) * wcol).sum() / denom
    amax = jnp.where(wcol > 0, jnp.abs(flat), 0.0).max()
    return mean, meansq, amax


def combine_moments(mean_b, meansq_b, amax_b, weights=None):
    """Fold per-example moments ``[B]`` (the DP path's vmapped singleton
    batches) into batch moments — weighted so padded examples vanish."""
    import jax.numpy as jnp
    if weights is None:
        return mean_b.mean(), meansq_b.mean(), amax_b.max()
    w = weights.astype(jnp.float32)
    denom = jnp.maximum(w.sum(), 1.0)
    return ((mean_b * w).sum() / denom, (meansq_b * w).sum() / denom,
            jnp.where(w > 0, amax_b, 0.0).max())


def moments_to_stats(mean, meansq, amax) -> dict:
    """Finalize moments into the reported cut-stat metric dict."""
    import jax.numpy as jnp
    var = jnp.maximum(meansq - jnp.square(mean), 0.0)
    return {"cut_mean": mean, "cut_std": jnp.sqrt(var), "cut_absmax": amax}


def clip_fraction(norms, clip_norm, weights=None):
    """Fraction of (valid) examples whose per-example gradient hit the
    clip: ``norm > C`` is exactly when ``clip_scales = min(1, C/norm)``
    bites.  ``norms`` are the (B,) pre-clip norms the DP clip kernel
    already computes; ``weights`` excludes padded rows.  ``clip_norm=inf``
    (clipping disabled) yields 0."""
    import jax.numpy as jnp
    clipped = (norms > clip_norm).astype(jnp.float32)
    if weights is None:
        return clipped.mean()
    w = weights.astype(jnp.float32)
    return (clipped * w).sum() / jnp.maximum(w.sum(), 1.0)


def observing_boundary(base_boundary, sink: list):
    """Wrap a transport/privacy boundary so the FIRST crossing's payload
    (front->middle — THE cut layer) is recorded into ``sink`` exactly as
    it ships (post-codec, post-noise).  The payload itself is returned
    unchanged, so observation never perturbs training math."""
    def fn(tree):
        out = tree if base_boundary is None else base_boundary(tree)
        sink.append(out)
        return out
    return fn


# ---------------------------------------------------------------------------
# host-side reductions — per-step stacks -> per-round x per-hospital
# ---------------------------------------------------------------------------

def _nanrow(n):
    return np.full((n,), np.nan)


def _masked_client_mean(arr, mask, n_clients) -> np.ndarray:
    """``[C, NB]`` values + validity mask -> per-hospital mean ``[C_real]``
    (phantom/padded rows sliced off)."""
    a = np.asarray(arr, np.float64)[:n_clients]
    m = np.asarray(mask, np.float64)[:n_clients]
    s, c = (a * m).sum(axis=1), m.sum(axis=1)
    with np.errstate(invalid="ignore"):
        return np.where(c > 0, s / np.maximum(c, 1.0), np.nan)


def _scheduled_client_mean(arr, sched, n_clients) -> np.ndarray:
    """``[S]`` per-step values in schedule order -> per-hospital mean."""
    a = np.asarray(arr, np.float64)
    out, cnt = np.zeros(n_clients), np.zeros(n_clients)
    for v, (c, _b) in zip(a, np.asarray(sched)):
        if c < n_clients:
            out[c] += v
            cnt[c] += 1
    with np.errstate(invalid="ignore"):
        return np.where(cnt > 0, out / np.maximum(cnt, 1.0), np.nan)


@dataclasses.dataclass
class RoundTelemetry:
    """One training round's reduced metrics: every value is a
    ``[n_clients]`` float array (NaN where a hospital took no step).

    Under per-round client subsampling ``participation`` lists the
    round's sampled GLOBAL hospital ids; metric columns of unsampled
    hospitals are NaN for that round."""
    round_index: int
    metrics: dict
    epsilon: np.ndarray | None = None
    participation: np.ndarray | None = None

    def scalars(self) -> dict:
        """Hospital-mean summary of each metric (for printing)."""
        out = {}
        for k, v in self.metrics.items():
            with np.errstate(invalid="ignore"):
                out[k] = float(np.nanmean(v)) if np.asarray(v).size else float("nan")
        if self.epsilon is not None:
            out["epsilon_max"] = float(np.max(self.epsilon))
        return out

    def to_json(self) -> dict:
        out = {"round": self.round_index,
               "metrics": {k: np.asarray(v, np.float64).tolist()
                           for k, v in self.metrics.items()}}
        if self.epsilon is not None:
            out["epsilon"] = np.asarray(self.epsilon, np.float64).tolist()
        if self.participation is not None:
            out["participation"] = [
                int(i) for i in np.asarray(self.participation)]
        return out


@dataclasses.dataclass
class RunTelemetry:
    """Whole observed run: one ``RoundTelemetry`` per round."""
    strategy: str
    n_clients: int
    rounds: list

    def metric(self, name: str) -> np.ndarray:
        """``[n_rounds, n_clients]`` stack of one metric across rounds."""
        return np.stack([r.metrics.get(name, _nanrow(self.n_clients))
                         for r in self.rounds])

    def to_json(self) -> dict:
        return {"strategy": self.strategy, "n_clients": self.n_clients,
                "rounds": [r.to_json() for r in self.rounds]}

    def table(self) -> str:
        """Markdown per-round summary table."""
        if not self.rounds:
            return "(no rounds observed)"
        keys = sorted({k for r in self.rounds for k in r.scalars()})
        lines = ["| round | " + " | ".join(keys) + " |",
                 "|---" * (len(keys) + 1) + "|"]
        for r in self.rounds:
            s = r.scalars()
            lines.append("| " + " | ".join(
                [str(r.round_index)]
                + [f"{s[k]:.4g}" if k in s and np.isfinite(s[k]) else "-"
                   for k in keys]) + " |")
        return "\n".join(lines)


def _per_metric(tel: Telemetry, loss, metrics: dict, reduce) -> dict:
    out = {}
    if tel.loss:
        out["loss"] = reduce(loss)
    for k, v in metrics.items():
        out[k] = reduce(v)
    return out


def rounds_client_major(tel: Telemetry, losses, metrics: dict, mask,
                        n_clients: int, extra: dict | None = None) -> list:
    """Reduce FL/centralized stacks ``[E, C, NB]`` (+ per-round ``extra``
    taps ``[E, C]``, e.g. the FedAvg update cosine) into per-round
    telemetry."""
    losses = np.asarray(losses)
    E = losses.shape[0]
    out = []
    for e in range(E):
        m = _per_metric(tel, losses[e],
                        {k: np.asarray(v)[e] for k, v in metrics.items()},
                        lambda a: _masked_client_mean(a, mask, n_clients))
        for k, v in (extra or {}).items():
            m[k] = np.asarray(v, np.float64)[e][:n_clients]
        out.append(RoundTelemetry(e, m))
    return out


def rounds_participation(tel: Telemetry, losses, metrics: dict, pack,
                         extra: dict | None = None) -> list:
    """Reduce a participating FL run's SLOT-major stacks ``[E, S, NB]``
    (+ per-round ``extra`` taps ``[E, S]``) into per-round telemetry over
    the GLOBAL hospital axis: each slot's per-round mean scatters to its
    global hospital's column, hospitals not sampled that round are NaN,
    and ``RoundTelemetry.participation`` records the round's sampled ids.
    """
    losses = np.asarray(losses)
    E, N = losses.shape[0], pack.n_global

    def scatter_slots(e):
        gid = np.asarray(pack.slot_gid[e])

        def reduce(a):
            a = np.asarray(a, np.float64)
            if a.ndim == 2:                       # [S, NB] per-step taps
                row = _masked_client_mean(a, pack.mask[e], a.shape[0])
            else:                                 # [S] per-round taps
                row = a
            out = _nanrow(N)
            for s, g in enumerate(gid):
                if g >= 0 and not np.isnan(row[s]):
                    out[g] = row[s]
            return out
        return reduce

    out = []
    for e in range(E):
        reduce = scatter_slots(e)
        m = _per_metric(tel, losses[e],
                        {k: np.asarray(v)[e] for k, v in metrics.items()},
                        reduce)
        for k, v in (extra or {}).items():
            m[k] = reduce(np.asarray(v, np.float64)[e])
        r = RoundTelemetry(e, m)
        r.participation = np.flatnonzero(np.asarray(pack.part_mask[e]))
        out.append(r)
    return out


def rounds_scheduled(tel: Telemetry, losses, metrics: dict, sched,
                     n_clients: int) -> list:
    """Reduce SL/SFLv2 stacks ``[E, S]`` through the schedule array."""
    losses = np.asarray(losses)
    out = []
    for e in range(losses.shape[0]):
        m = _per_metric(
            tel, losses[e],
            {k: np.asarray(v)[e] for k, v in metrics.items()},
            lambda a: _scheduled_client_mean(a, sched, n_clients))
        out.append(RoundTelemetry(e, m))
    return out


def rounds_sync(tel: Telemetry, losses, metrics: dict,
                n_clients: int) -> list:
    """Reduce SFLv3/v1 stacks ``[E, S, C]`` (every client active every
    synchronous step; placement phantom columns sliced off)."""
    losses = np.asarray(losses)
    out = []
    for e in range(losses.shape[0]):
        m = _per_metric(
            tel, losses[e],
            {k: np.asarray(v)[e] for k, v in metrics.items()},
            lambda a: np.asarray(a, np.float64)[:, :n_clients].mean(axis=0)
            if np.asarray(a).size else _nanrow(n_clients))
        out.append(RoundTelemetry(e, m))
    return out


def pack_client_major(values: list, n_batches: list):
    """Stepwise-engine helper: a client-major flat list of per-step values
    -> (``[C, NB_max]`` array, validity mask) matching the compiled
    layout, so both engines reduce through the same code."""
    C = len(n_batches)
    NB = max(n_batches, default=0)
    arr = np.zeros((C, max(NB, 1)))
    mask = np.zeros((C, max(NB, 1)), bool)
    it = iter(values)
    for c, nb in enumerate(n_batches):
        for b in range(nb):
            arr[c, b] = next(it)
            mask[c, b] = True
    return arr, mask


# ---------------------------------------------------------------------------
# per-round privacy epsilon series
# ---------------------------------------------------------------------------

def epsilon_rounds(privacy, logs, n_samples: list, batch_size: int,
                   pooled: bool = False, q_scale: float = 1.0,
                   steps_override: list | None = None) -> np.ndarray | None:
    """``[n_rounds, n_clients]`` cumulative (eps at delta) after each
    round, composed from the SAME per-round step counts and sampling
    rates the strategies feed the real accountant (``EpochLog.
    client_steps`` / ``steps``), so the last row equals the run's
    ``privacy_report`` epsilons when this run is the only training.

    ``pooled`` is the centralized case: every hospital's records sit in
    the pooled set, so each composes at the pooled sampling rate over the
    pooled step count.

    Under client subsampling (``Participation`` with sampling randomness)
    EVERY hospital composes EVERY round at the amplified rate
    ``q_scale * q_batch`` over the step count it would contribute when
    sampled — pass that count per hospital as ``steps_override`` (the
    realized ``client_steps`` are zero for unsampled rounds and must NOT
    be used, since amplification accounts the sampling probability, not
    the realization).
    """
    if privacy is None or not privacy.dp_enabled:
        return None
    from repro.privacy.accountant import RDPAccountant
    n_clients = len(n_samples)
    n_pool = sum(n_samples)
    accts = [RDPAccountant(privacy.noise_multiplier, privacy.delta)
             for _ in range(n_clients)]
    out = np.zeros((len(logs), n_clients))
    for e, log in enumerate(logs):
        for c in range(n_clients):
            if pooled:
                q, steps = (min(batch_size / max(n_pool, 1), 1.0),
                            log.steps)
            else:
                q = min(batch_size / max(n_samples[c], 1), 1.0)
                if steps_override is not None:
                    steps = steps_override[c]
                else:
                    steps = (log.client_steps[c]
                             if log.client_steps is not None else log.steps)
            accts[c].step(q * q_scale, steps)
            out[e, c] = accts[c].epsilon()[0]
    return out


__all__ = ["Telemetry", "RoundTelemetry", "RunTelemetry", "as_telemetry",
           "global_norm", "stacked_global_norm", "payload_moments",
           "combine_moments", "moments_to_stats", "clip_fraction",
           "observing_boundary", "rounds_client_major",
           "rounds_participation", "rounds_scheduled", "rounds_sync",
           "pack_client_major", "epsilon_rounds"]
