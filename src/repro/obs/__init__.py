"""repro.obs — observability for the compiled multi-hospital engine.

Four layers, threaded through the strategy stack (DESIGN.md §12):

  * ``telemetry``  — in-program metric taps riding the engine's scans
                     (``Telemetry`` spec; per-round x per-hospital stats).
  * ``trace``      — host-side span tree merged with ``wire.simulator``
                     transfer timelines and per-round RDP epsilon into one
                     Chrome-trace/Perfetto JSON.
  * ``profile``    — ``jax.profiler`` wrapper + compile-time / dispatch /
                     HLO-cost capture via ``launch.hlo_analysis``.
  * ``report``     — ``RUNLOG_*.json`` + markdown run reports.
"""

from repro.obs.telemetry import (RoundTelemetry, RunTelemetry, Telemetry,
                                 as_telemetry)
from repro.obs.trace import (PID_SERVING, Tracer, merge_events,
                             round_events, wire_events, write_chrome_trace)
from repro.obs.profile import cost_summary, hlo_cost, jax_profile
from repro.obs.report import render_markdown, write_runlog

__all__ = ["Telemetry", "RoundTelemetry", "RunTelemetry", "as_telemetry",
           "Tracer", "merge_events", "round_events", "wire_events",
           "write_chrome_trace", "PID_SERVING", "cost_summary", "hlo_cost",
           "jax_profile", "render_markdown", "write_runlog"]
