"""Run reports — ``RUNLOG_<name>.json`` + a markdown summary per run.

The JSON runlog is the machine-readable record: the full per-round x
per-hospital telemetry (``RunTelemetry.to_json``), the strategy's cost
summary (dispatch count, compile time, HLO flop/byte estimates) and any
extra sections the caller supplies (wire accounting, eval metrics).  The
markdown report renders the same telemetry as a per-round table for
humans — CI uploads both as artifacts from the observed example run.
"""

from __future__ import annotations

import json
import os


def write_runlog(out_dir, name: str, telemetry=None, cost=None,
                 extra: dict | None = None) -> str:
    """Write ``RUNLOG_<name>.json`` under ``out_dir`` and return its path."""
    os.makedirs(str(out_dir), exist_ok=True)
    doc: dict = {"name": name}
    if telemetry is not None:
        doc["telemetry"] = telemetry.to_json()
    if cost is not None:
        doc["cost"] = cost
    if extra:
        doc.update(extra)
    path = os.path.join(str(out_dir), f"RUNLOG_{name}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, default=float)
    return path


def render_markdown(telemetry, cost=None, title: str | None = None) -> str:
    """Markdown run report: per-round telemetry table + cost footer."""
    lines = [f"# Run report: {title or telemetry.strategy}", ""]
    lines.append(telemetry.table())
    if cost is not None:
        lines += ["", "## Cost", ""]
        for k, v in cost.items():
            if isinstance(v, dict):
                v = json.dumps(v, default=float)
            lines.append(f"- **{k}**: {v}")
    return "\n".join(lines) + "\n"


def write_report(out_dir, name: str, telemetry, cost=None) -> str:
    """Write ``REPORT_<name>.md`` alongside the runlog."""
    os.makedirs(str(out_dir), exist_ok=True)
    path = os.path.join(str(out_dir), f"REPORT_{name}.md")
    with open(path, "w") as f:
        f.write(render_markdown(telemetry, cost, title=name))
    return path


__all__ = ["write_runlog", "render_markdown", "write_report"]
