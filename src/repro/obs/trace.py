"""Run tracing — one merged Chrome-trace/Perfetto JSON per run.

Three clock domains meet here and each gets its own ``pid`` lane:

  * **engine host** (``PID_ENGINE``): real wall-clock spans recorded by
    ``Tracer`` around the strategy's host phases (run -> pack -> dispatch
    -> collect; per-round spans on the stepwise path).  The compiled
    engine executes every round inside ONE dispatch, so ``round_events``
    subdivides the dispatch span into equal per-round slices (flagged
    ``synthetic``) to carry per-round telemetry args — loss, grad norms —
    and the cumulative RDP epsilon as Chrome counter (ph "C") events.
  * **wire** (``PID_WIRE``): the *simulated*-time transfer timelines from
    ``wire.simulator.timeline_from_accounting`` — per-client tracks of
    upload/download events with tag + byte args.  Simulated seconds are
    mapped 1:1 onto trace microseconds; the lane is a model of the wire,
    not a measurement, and is labelled as such.
  * **privacy** counters ride in the engine lane as ``epsilon[c]``
    counter tracks, one per hospital, stepping at each round boundary.

``write_chrome_trace`` emits the standard ``{"traceEvents": [...]}`` JSON
that chrome://tracing and https://ui.perfetto.dev load directly.
"""

from __future__ import annotations

import contextlib
import json
import time

import numpy as np

PID_ENGINE = 1
PID_WIRE = 2
PID_SERVING = 3


def _meta(pid, name, tid=None, tname=None):
    ev = [{"name": "process_name", "ph": "M", "pid": pid,
           "args": {"name": name}}]
    if tid is not None:
        ev.append({"name": "thread_name", "ph": "M", "pid": pid,
                   "tid": tid, "args": {"name": tname}})
    return ev


class Tracer:
    """Host-side span tree: nested ``with tracer.span(name):`` blocks
    become Chrome complete ("X") events on one engine-host track.  A
    strategy given to ``Strategy.attach_tracer`` records its pack /
    dispatch / collect phases here."""

    def __init__(self, pid: int = PID_ENGINE, tid: int = 1):
        self.pid, self.tid = pid, tid
        self.events: list = []
        self._depth = 0
        self._t0 = time.perf_counter()

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    @contextlib.contextmanager
    def span(self, name: str, **args):
        t0 = self._now_us()
        self._depth += 1
        try:
            yield self
        finally:
            self._depth -= 1
            self.events.append({
                "name": name, "ph": "X", "ts": t0,
                "dur": max(self._now_us() - t0, 0.01),
                "pid": self.pid, "tid": self.tid,
                "args": {**args, "depth": self._depth}})

    def event(self, name: str, t0_s: float, t1_s: float,
              tid: int | None = None, **args) -> None:
        """Record an externally-timed complete span from a pair of
        ``tracer.now()`` readings (seconds since this tracer's epoch) —
        used by the serving front end, whose phases are timed where they
        happen (enqueue in the caller, dispatch in the batcher thread)
        rather than around a single ``with`` block."""
        self.events.append({
            "name": name, "ph": "X", "ts": t0_s * 1e6,
            "dur": max((t1_s - t0_s) * 1e6, 0.01),
            "pid": self.pid, "tid": self.tid if tid is None else tid,
            "args": args})

    def now(self) -> float:
        """Seconds since this tracer's epoch (pairs with ``event``)."""
        return time.perf_counter() - self._t0

    def find(self, name: str) -> dict | None:
        """Most recent finished span with this name (e.g. "dispatch")."""
        for ev in reversed(self.events):
            if ev["name"] == name:
                return ev
        return None

    def trace_events(self) -> list:
        return _meta(self.pid, "engine host", self.tid, "strategy") \
            + list(self.events)


def round_events(run_telemetry, dispatch_span=None, pid: int = PID_ENGINE,
                 tid: int = 2) -> list:
    """Per-round telemetry as trace events.

    The compiled whole-run program gives the host no per-round timing —
    every round lives inside one dispatch — so rounds are laid out as
    equal slices of the dispatch span (or of a unit span when no tracer
    ran), flagged ``"synthetic": True``.  Each slice carries the round's
    hospital-mean metrics as args; the cumulative per-hospital RDP
    epsilon becomes counter ("C") tracks stepping at round boundaries.
    """
    rounds = run_telemetry.rounds
    if not rounds:
        return []
    if dispatch_span is not None:
        t0, dur = dispatch_span["ts"], dispatch_span["dur"]
    else:
        t0, dur = 0.0, float(len(rounds)) * 1e6
    slice_us = dur / len(rounds)
    out = _meta(pid, "engine host", tid,
                f"rounds ({run_telemetry.strategy}, synthetic)")
    for i, r in enumerate(rounds):
        args = {"synthetic": True}
        for k, v in r.scalars().items():
            if np.isfinite(v):
                args[k] = round(float(v), 6)
        out.append({"name": f"round {r.round_index}", "ph": "X",
                    "ts": t0 + i * slice_us, "dur": slice_us,
                    "pid": pid, "tid": tid, "args": args})
        if r.epsilon is not None:
            eps = np.asarray(r.epsilon, np.float64)
            out.append({"name": f"epsilon ({run_telemetry.strategy})",
                        "ph": "C", "ts": t0 + (i + 1) * slice_us,
                        "pid": pid,
                        "args": {f"hospital{c}": round(float(eps[c]), 6)
                                 for c in range(eps.shape[0])}})
    return out


def wire_events(sim_result, pid: int = PID_WIRE, label: str = "") -> list:
    """``wire.simulator.SimResult`` transfer events as per-client trace
    tracks (simulated seconds -> trace microseconds)."""
    name = f"wire (simulated{', ' + label if label else ''})"
    out = _meta(pid, name)
    clients = sorted({e.client for e in sim_result.events})
    for tid, c in enumerate(clients, start=1):
        out += _meta(pid, name, tid, f"client {c}")[1:]
        for e in sim_result.events:
            if e.client != c:
                continue
            out.append({"name": e.tag, "ph": "X", "ts": e.t_start * 1e6,
                        "dur": max((e.t_end - e.t_start) * 1e6, 0.01),
                        "pid": pid, "tid": tid,
                        "args": {"bytes": int(e.nbytes),
                                 "direction": e.direction}})
    return out


def merge_events(*event_lists, pid_offset: int = 0) -> list:
    """Concatenate event lists into one trace; ``pid_offset`` shifts every
    pid of the merged lists so several strategies' lanes can coexist in
    one file (offset by, say, 10 per strategy)."""
    out = []
    for evs in event_lists:
        for e in evs:
            e = dict(e)
            e["pid"] = e.get("pid", 0) + pid_offset
            out.append(e)
    return out


def write_chrome_trace(events: list, path) -> str:
    """Write ``{"traceEvents": [...]}`` JSON loadable by chrome://tracing
    and Perfetto."""
    path = str(path)
    with open(path, "w") as f:
        json.dump({"traceEvents": events,
                   "displayTimeUnit": "ms"}, f, indent=None)
    return path


__all__ = ["Tracer", "round_events", "wire_events", "merge_events",
           "write_chrome_trace", "PID_ENGINE", "PID_WIRE", "PID_SERVING"]
