"""Profiling — compile time, dispatch counts, and HLO cost per strategy.

Three independent captures feeding the per-strategy cost summary (the
ROADMAP's roofline item):

  * ``jax_profile(dir)``: context manager around ``jax.profiler`` device
    tracing (TensorBoard/Perfetto dump) — best-effort, a no-op when the
    profiler is unavailable in the environment.
  * ``hlo_cost(strategy)``: every compiled ``Strategy.run`` stashes its
    jitted whole-run callable + concrete args as
    ``strategy._last_run_invocation``; this re-lowers it (timed — on a
    warm jit cache the *first* call's compile dominates, so the AOT
    ``lower().compile()`` here measures a fresh backend compile), then
    feeds the optimized HLO text through ``launch.hlo_analysis.analyze``
    for flop / HBM-byte / collective estimates (while-loop trip counts
    included, so a whole-run program's cost covers every round).
  * dispatch counts: ``Strategy._dispatches`` tallies every
    host->device training-program invocation (compiled epoch/run calls,
    stepwise per-batch steps) — the compiled whole-run path shows 1 per
    ``run``, the stepwise oracle shows one per mini-batch.
"""

from __future__ import annotations

import contextlib
import time


@contextlib.contextmanager
def jax_profile(trace_dir):
    """Wrap a block in ``jax.profiler`` device tracing writing to
    ``trace_dir`` (viewable in TensorBoard / Perfetto).  Best-effort: when
    the profiler cannot start (no backend support, already active) the
    block simply runs untraced."""
    import jax
    started = False
    try:
        jax.profiler.start_trace(str(trace_dir))
        started = True
    except Exception:
        pass
    try:
        yield
    finally:
        if started:
            try:
                jax.profiler.stop_trace()
            except Exception:
                pass


def hlo_cost(strategy) -> dict | None:
    """Compile-time + HLO cost model of the strategy's last compiled run
    program.  Returns None when the strategy has not dispatched a
    compiled run yet (stepwise engine, degenerate runs)."""
    inv = getattr(strategy, "_last_run_invocation", None)
    if inv is None:
        return None
    fn, args = inv
    from repro.launch import hlo_analysis
    t0 = time.perf_counter()
    lowered = fn.lower(*args)
    compiled = lowered.compile()
    compile_s = time.perf_counter() - t0
    cost = hlo_analysis.analyze(compiled.as_text())
    # compiler's own buffer-assignment view: temp (peak scratch), argument,
    # output and donation-aliased bytes — the donated whole-run programs
    # show their HBM saving in alias_size vs argument_size
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for f in ("temp_size_in_bytes", "argument_size_in_bytes",
                  "output_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            v = getattr(ma, f, None)
            if v is not None:
                mem[f] = int(v)
    except Exception:
        pass                       # backend without memory_analysis support
    if mem:
        cost = {**cost, "memory": mem}
    return {"compile_seconds": compile_s, **cost}


def cost_summary(strategy, wall_seconds: float | None = None,
                 total_steps: int | None = None) -> dict:
    """Per-strategy cost row: dispatch count, compile time, HLO flop/byte
    estimates, and steps/s when the caller timed the run."""
    out = {"strategy": strategy.name, "engine": strategy.engine,
           "dispatches": getattr(strategy, "_dispatches", 0),
           "run_calls": getattr(strategy, "_run_calls", 0)}
    hlo = hlo_cost(strategy)
    if hlo is not None:
        out["hlo"] = hlo
    if wall_seconds is not None:
        out["wall_seconds"] = wall_seconds
        if total_steps:
            out["steps_per_s"] = total_steps / max(wall_seconds, 1e-9)
    return out


__all__ = ["jax_profile", "hlo_cost", "cost_summary"]
