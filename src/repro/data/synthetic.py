"""Synthetic stand-ins for the paper's data (see DESIGN.md §1 — data gate).

``make_cxr_clients`` emits a 5-hospital non-IID binary-classification task
mimicking the paper's TB chest-X-ray setup: positives carry bright nodular
blobs on a smooth background; each client has its own scanner-like domain
shift (contrast, noise floor, blob intensity, spatial prior).  Prevalence is
50% in train and 10% in val/test, matching §3.1 of the paper.

``token_stream`` emits an order-2 Markov token source for LM smoke/e2e runs
(a learnable distribution, so small-model loss curves actually move).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ClientData:
    name: str
    train: dict      # {"image": (N,H,W,1) f32, "label": (N,) f32, "mask": (N,H,W,1)}
    val: dict
    test: dict


def _smooth_noise(rng, n, size, sigma):
    low = rng.normal(0, 1, (n, size // 8, size // 8)).astype(np.float32)
    img = np.kron(low, np.ones((8, 8), np.float32))       # cheap upsample
    img += rng.normal(0, sigma, (n, size, size)).astype(np.float32)
    return img


def _add_blobs(rng, img, mask, intensity, center_bias, n_blobs=(1, 4)):
    n, size, _ = img.shape
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32)
    for i in range(n):
        k = rng.integers(n_blobs[0], n_blobs[1] + 1)
        for _ in range(k):
            cx = np.clip(rng.normal(center_bias[0], 0.2), 0.1, 0.9) * size
            cy = np.clip(rng.normal(center_bias[1], 0.2), 0.1, 0.9) * size
            r = rng.uniform(size * 0.08, size * 0.18)
            blob = np.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2) / (2 * r * r)))
            img[i] += intensity * blob
            mask[i] |= blob > 0.4
    return img, mask


def _make_split(rng, n, size, prevalence, shift):
    labels = (rng.uniform(0, 1, n) < prevalence).astype(np.float32)
    img = _smooth_noise(rng, n, size, shift["noise"])
    mask = np.zeros((n, size, size), bool)
    pos = labels > 0.5
    if pos.any():
        img[pos], mask[pos] = _add_blobs(
            rng, img[pos], mask[pos], shift["intensity"], shift["center"])
    img = shift["gain"] * img + shift["offset"]
    img = np.tanh(img).astype(np.float32)
    return {"image": img[..., None], "label": labels,
            "mask": mask[..., None].astype(np.float32)}


def make_cxr_clients(seed=0, n_clients=5, train_per_client=120,
                     val_per_client=60, test_per_client=60, image_size=64,
                     size_skew=None, label_skew_alpha=None):
    """``train_per_client`` may be an int or a per-client list (the paper's
    hospitals have very different data volumes — 3772 vs 880).

    Two cross-device-realism knobs (both OFF by default — the defaults
    stay byte-identical because each knob draws from its OWN seeded
    stream, never the shared shift rng):

    * ``size_skew``: a positive float — per-client train sizes are drawn
      log-normal around ``train_per_client`` with sigma ``size_skew``
      (min 2 samples), mimicking the heavy-tailed hospital volumes of a
      real federation.  Ignored when ``train_per_client`` is a list.
    * ``label_skew_alpha``: Dirichlet/Beta concentration — each client's
      TRAIN prevalence is drawn ``Beta(alpha, alpha)`` instead of the
      paper's uniform 50% (small alpha => clients specialize toward
      mostly-positive or mostly-negative label pools).  Val/test keep the
      paper's 10% prevalence.
    """
    rng = np.random.default_rng(seed)
    sizes = None
    if size_skew is not None and not isinstance(train_per_client,
                                                (list, tuple)):
        if size_skew <= 0:
            raise ValueError("size_skew must be positive")
        size_rng = np.random.default_rng([seed, 1011])
        sizes = np.maximum(2, np.round(
            train_per_client
            * np.exp(size_rng.normal(0.0, size_skew, n_clients))
        ).astype(int))
    prevs = None
    if label_skew_alpha is not None:
        if label_skew_alpha <= 0:
            raise ValueError("label_skew_alpha must be positive")
        label_rng = np.random.default_rng([seed, 2022])
        prevs = label_rng.beta(label_skew_alpha, label_skew_alpha,
                               n_clients)
    clients = []
    for c in range(n_clients):
        # strong non-IID scanner shift: even hospitals see BRIGHT lesions,
        # odd hospitals see DARK ones, on different backgrounds — a shared
        # (server) segment trained sequentially must not forget either mode
        polarity = 1.0 if c % 2 == 0 else -1.0
        shift = {
            "noise": rng.uniform(0.08, 0.3),
            "gain": rng.uniform(0.5, 1.5),
            "offset": rng.uniform(-0.4, 0.4),
            "intensity": polarity * rng.uniform(2.0, 3.5),
            "center": (rng.uniform(0.25, 0.75), rng.uniform(0.25, 0.75)),
        }
        n_tr = (train_per_client[c] if isinstance(train_per_client,
                                                  (list, tuple))
                else train_per_client)
        if sizes is not None:
            n_tr = int(sizes[c])
        tr_prev = 0.5 if prevs is None else float(prevs[c])
        clients.append(ClientData(
            name=f"DT{c + 1}",
            train=_make_split(rng, n_tr, image_size, tr_prev, shift),
            val=_make_split(rng, val_per_client, image_size, 0.1, shift),
            test=_make_split(rng, test_per_client, image_size, 0.1, shift)))
    return clients


def pooled(clients, split):
    """Centralized pooling of all client splits."""
    keys = getattr(clients[0], split).keys()
    return {k: np.concatenate([getattr(c, split)[k] for c in clients])
            for k in keys}


def batches(data, batch_size, rng=None, drop_remainder=True):
    n = len(data["label"])
    idx = np.arange(n)
    if rng is not None:
        rng.shuffle(idx)
    stop = (n // batch_size) * batch_size if drop_remainder else n
    for s in range(0, stop, batch_size):
        sel = idx[s:s + batch_size]
        yield {k: v[sel] for k, v in data.items()}


# ---------------------------------------------------------------------------
# token streams for the LM architectures
# ---------------------------------------------------------------------------

def token_stream(seed, vocab, n_seqs, seq_len, order=1):
    """Markov token source — learnable structure for tiny-LM e2e runs."""
    rng = np.random.default_rng(seed)
    v_eff = min(vocab, 256)
    trans = rng.dirichlet(np.full(v_eff, 0.1), size=v_eff).astype(np.float32)
    cum = np.cumsum(trans, axis=1)
    toks = np.zeros((n_seqs, seq_len), np.int32)
    state = rng.integers(0, v_eff, n_seqs)
    for t in range(seq_len):
        u = rng.uniform(0, 1, n_seqs).astype(np.float32)
        state = (cum[state] < u[:, None]).sum(axis=1).clip(0, v_eff - 1)
        toks[:, t] = state
    return toks


def lm_clients(seed, vocab, n_clients, seqs_per_client, seq_len):
    """Per-client token sources with different Markov chains (non-IID)."""
    return [token_stream(seed + 17 * c, vocab, seqs_per_client, seq_len)
            for c in range(n_clients)]
