"""Fused cut-layer kernel — int8 roundtrip + masked Gaussian noise.

The SL/SFL hot path runs, per cut-layer crossing, the codec quantize, the
dequantize, and (with ``PrivacyConfig.cut_noise_std``) a masked per-example
Gaussian noise add as separate ops — three HBM round-trips for one logical
transformation of the smashed activations.  This kernel fuses all of it:
each grid cell reads one (block_rows, D) activation tile into VMEM, does the
per-row absmax int8 quantize -> dequantize (identical arithmetic to
``kernels/act_compress``) and adds the pre-scaled noise tile, writing only
the final boundary payload.

The Gaussian draws themselves stay OUTSIDE the kernel: bit-exactness with
the unfused composition requires the exact ``jax.random.fold_in`` stream
(threefry), which an in-kernel ``pltpu.prng_random_bits`` cannot reproduce.
``privacy.dpsgd._leaf_noise`` draws AND std-scales the noise with the one
shared subgraph both paths consume (sharing it is what keeps XLA's
constant-merging rewrites identical across the two programs); the kernel
applies the per-example pad mask and the final add in the same f32 op
order — so the fused boundary is bit-identical to
codec-roundtrip-then-noise.

Tiling: grid over row blocks, one row per flattened example-row; the
per-example pad mask enters as a (block_rows, 1) f32 weight column.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import INTERPRET, CompilerParams


def pin_product(v, zero_src):
    """Force ``v``'s producing multiply to round before a following add.

    XLA's CPU backend may contract ``mul + add`` chains into FMAs — one
    rounding instead of two — and fusion duplicates producers, so neither
    ``optimization_barrier`` nor a bitcast reliably splits the chain.  The
    runtime zero cannot be constant-folded (strict IEEE: ``x * 0`` is
    ``nan`` for non-finite x, and a ±0 addend's sign is data-dependent),
    and if the compiler DOES contract, ``fma(a, b, ±0) == round(a * b)``
    exactly — either way ``v`` rounds on its own.  ``zero_src`` must be
    finite (activations/noise draws are).
    """
    return v + zero_src * 0.0


def _int8_roundtrip(x):
    """Per-row absmax int8 quantize + dequantize in f32 (VMEM-resident).

    Must stay arithmetically identical to ``act_compress._quant_kernel`` +
    ``_dequant_kernel`` — the fused boundary is gated on bit-exactness
    against that composition.
    """
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _roundtrip_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = _int8_roundtrip(x).astype(o_ref.dtype)


def _noise_kernel(x_ref, z_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    z = z_ref[...]
    # the dequant multiply and the mask multiply must each take their OWN
    # f32 rounding before the final add, exactly as the unfused composition
    # (dequant pinned at its kernel boundary, mask pinned in
    # cut_noise_boundary) — see pin_product
    r = pin_product(_int8_roundtrip(x), x).astype(o_ref.dtype)
    z = pin_product(z * w_ref[...], z)
    o_ref[...] = r + z.astype(o_ref.dtype)


def roundtrip_pallas(x, *, block_rows=256, interpret=INTERPRET):
    """x: (T, D) -> int8-roundtripped (T, D), one fused pass."""
    t, d = x.shape
    block_rows = min(block_rows, t)
    assert t % block_rows == 0
    grid = (t // block_rows,)
    return pl.pallas_call(
        _roundtrip_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)


def noise_roundtrip_pallas(x, z, w, *, block_rows=256, interpret=INTERPRET):
    """x: (T, D), z: f32 (T, D) pre-scaled noise, w: f32 (T, 1) row weights
    -> roundtrip(x) + (z * w).astype(x.dtype), one fused pass."""
    t, d = x.shape
    block_rows = min(block_rows, t)
    assert t % block_rows == 0
    grid = (t // block_rows,)
    return pl.pallas_call(
        _noise_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, z, w)
