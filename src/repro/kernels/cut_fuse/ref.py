"""Eager oracle for the fused cut-layer kernel.

``noise_roundtrip_ref`` is codec-roundtrip-then-noise in the op order of
``wire.codec.Int8Codec.roundtrip`` followed by
``privacy.dpsgd.cut_noise_boundary``.  The fused kernel's BIT-equality gate
runs against the unfused IN-GRAPH composition (same execution mode on both
sides — see tests/test_kernels.py); this pure-eager oracle is a closeness
gate, since jit may strength-reduce the quantizer division by 1 ulp.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.act_compress.ref import roundtrip_ref


def noise_roundtrip_ref(x, z, std: float, weights=None):
    """x: (B, ...); z: f32 standard normal, x.shape; weights: (B,) or None."""
    r = roundtrip_ref(x)
    z = float(std) * z
    if weights is not None:
        b = x.shape[0]
        z = z * weights.astype(jnp.float32).reshape(
            (b,) + (1,) * (x.ndim - 1))
    return r + z.astype(x.dtype)
