"""Jitted wrappers + STE for the fused cut-layer kernel.

``roundtrip_boundary`` is the drop-in fused replacement for
``act_compress.ops.compress_boundary`` (one pallas_call instead of a
quantize + dequantize pair); ``cut_noise_roundtrip`` additionally folds the
masked per-example Gaussian cut-noise add into the same pass.  Both
backpropagate straight-through in ``x`` — the link is quantized and noised,
client-side gradients stay full precision — and the noise/mask inputs get
zero cotangents (their upstream is PRNG bits / the pad mask, never
differentiated).

The caller draws and std-scales ``z`` with the shared
``privacy.dpsgd._leaf_noise`` subgraph (the identical fold_in stream the
unfused path consumes); the mask multiply and the add run INSIDE the
kernel with pinned per-op rounding, so fused == unfused bitwise (gated in
tests/test_kernels.py).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.kernels.cut_fuse.cut_fuse import (noise_roundtrip_pallas,
                                             pin_product, roundtrip_pallas)
from repro.kernels.compat import INTERPRET as _INTERPRET


@jax.jit
def fused_roundtrip(x):
    """Per-row absmax int8 quantize+dequantize of ``x`` in one kernel."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    block = min(256, max(8, x2.shape[0]))
    pad = (-x2.shape[0]) % block
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = roundtrip_pallas(x2, block_rows=block, interpret=_INTERPRET)
    n = math.prod(shape[:-1])
    return out[:n].reshape(shape)


def _noise_impl(x, z, w):
    """roundtrip(x) + (z * w_row).astype(x.dtype), fused.

    ``z`` is the pre-scaled f32 noise (``dpsgd._leaf_noise``); ``w`` the
    (B,) f32 per-example weight column — each flattened activation row
    inherits its example's weight.  1-D leaves (several examples per
    quantization row) fall back to roundtrip-then-add — same arithmetic,
    still one roundtrip kernel.
    """
    shape = x.shape
    if x.ndim < 2:
        zw = pin_product(z * w, z)
        return pin_product(fused_roundtrip(x), x) + zw.astype(x.dtype)
    x2 = x.reshape(-1, shape[-1])
    z2 = z.reshape(-1, shape[-1])
    rows_per_example = math.prod(shape[1:-1])
    w2 = jnp.repeat(w, rows_per_example).reshape(-1, 1)
    block = min(256, max(8, x2.shape[0]))
    pad = (-x2.shape[0]) % block
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
        z2 = jnp.pad(z2, ((0, pad), (0, 0)))
        w2 = jnp.pad(w2, ((0, pad), (0, 0)))
    out = noise_roundtrip_pallas(x2, z2, w2, block_rows=block,
                                 interpret=_INTERPRET)
    n = math.prod(shape[:-1])
    return out[:n].reshape(shape)


@jax.custom_vjp
def cut_noise_fused(x, z, w):
    return _noise_impl(x, z, w)


def _fwd(x, z, w):
    return _noise_impl(x, z, w), (z, w)


def _bwd(res, g):
    z, w = res
    # straight-through in x; z/w come from PRNG bits / the pad mask
    return (g, jnp.zeros_like(z), jnp.zeros_like(w))


cut_noise_fused.defvjp(_fwd, _bwd)


def cut_noise_roundtrip(x, z, weights=None):
    """Fused codec-roundtrip + masked per-example cut noise for one leaf.

    x: (B, ...) boundary activation leaf; z: pre-scaled f32 noise of
    ``x.shape`` drawn by ``dpsgd._leaf_noise`` (the unfused fold_in
    stream); weights: optional (B,) 0/1 validity mask (pad-and-mask rows).
    """
    b = x.shape[0]
    w = (jnp.ones((b,), jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    return cut_noise_fused(x, z, w)


@jax.custom_vjp
def roundtrip_boundary(x):
    return fused_roundtrip(x)


def _rt_fwd(x):
    return roundtrip_boundary(x), None


def _rt_bwd(_, g):
    return (g,)       # straight-through


roundtrip_boundary.defvjp(_rt_fwd, _rt_bwd)
