"""Pure-jnp oracle for the dp_clip kernels (materializes everything)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def sqnorms_ref(g):
    """g: (B, D) -> (B, 1) per-example sums of squares."""
    g = g.astype(jnp.float32)
    return jnp.sum(g * g, axis=-1, keepdims=True)


def scale_accum_ref(g, scales):
    """g: (B, D), scales: (B, 1) -> (1, D) of sum_b scales[b] * g[b]."""
    return jnp.sum(g.astype(jnp.float32) * scales, axis=0, keepdims=True)


def clip_scales(sq_norms, clip_norm):
    """min(1, C/||g||) from per-example squared norms; C=inf -> all ones."""
    norms = jnp.sqrt(jnp.maximum(sq_norms, 0.0))
    if not math.isfinite(clip_norm):
        return jnp.ones_like(norms)
    return jnp.minimum(1.0, clip_norm / jnp.maximum(norms, 1e-12))


def clip_accumulate_ref(per_example_grads, clip_norm):
    """Reference for the whole fused path over a per-example grad PYTREE.

    per_example_grads: pytree whose leaves carry a leading batch axis B.
    Returns (clipped-sum tree [no batch axis], (B,) per-example L2 norms).
    """
    leaves = jax.tree.leaves(per_example_grads)
    b = leaves[0].shape[0]
    sq = sum(sqnorms_ref(l.reshape(b, -1)) for l in leaves)
    scales = clip_scales(sq, clip_norm)

    def leaf_sum(l):
        flat = l.reshape(b, -1).astype(jnp.float32)
        return scale_accum_ref(flat, scales).reshape(l.shape[1:])

    out = jax.tree.map(leaf_sum, per_example_grads)
    return out, jnp.sqrt(jnp.maximum(sq, 0.0)).reshape(-1)
