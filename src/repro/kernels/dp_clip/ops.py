"""Jitted pytree-level wrapper around the dp_clip Pallas kernels.

``clip_accumulate`` is the DP-SGD hot path: per-example gradient pytree in,
(clipped sum, per-example norms) out.  Norms compose ACROSS leaves (the clip
factor is one scalar per example over the whole parameter vector), so the
ops layer runs the squared-norm kernel leaf-by-leaf, combines, and feeds the
shared scale column to the scale-fused accumulation kernel.

Leaves are zero-padded to kernel tiles (batch to sublane multiples, features
to lane multiples); zero rows/columns contribute nothing to norms or sums.
Off-TPU the kernels run in interpret mode (``kernels.compat.INTERPRET``).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.compat import INTERPRET as _INTERPRET
from repro.kernels.dp_clip.dp_clip import scale_accum_pallas, sqnorms_pallas
from repro.kernels.dp_clip.ref import clip_scales


def _pad2d(x, row_mult=8, col_mult=128):
    b, d = x.shape
    pb, pd = (-b) % row_mult, (-d) % col_mult
    if pb or pd:
        x = jnp.pad(x, ((0, pb), (0, pd)))
    return x


def _block(d: int) -> int:
    """Largest lane-aligned feature block that tiles ``d`` exactly."""
    for cand in (512, 384, 256, 128):
        if d % cand == 0:
            return cand
    return d


def _flat(leaf):
    return leaf.reshape(leaf.shape[0], -1)


@partial(jax.jit, static_argnames=("clip_norm",))
def clip_accumulate(per_example_grads, clip_norm: float):
    """Pytree of (B, ...) per-example grads -> (clipped-sum tree, (B,) norms).

    ``clip_norm`` is static (from PrivacyConfig); ``inf`` disables clipping
    but still fuses the batch reduction.
    """
    leaves, treedef = jax.tree.flatten(per_example_grads)
    b = leaves[0].shape[0]

    sq = jnp.zeros((b, 1), jnp.float32)
    padded = []
    for l in leaves:
        x = _pad2d(_flat(l))
        padded.append(x)
        sq += sqnorms_pallas(x, block_d=_block(x.shape[1]),
                             interpret=_INTERPRET)[:b]
    scales = clip_scales(sq, clip_norm)
    s_pad = jnp.pad(scales, ((0, padded[0].shape[0] - b), (0, 0)))

    sums = []
    for l, x in zip(leaves, padded):
        acc = scale_accum_pallas(x, s_pad[:x.shape[0]],
                                 block_d=_block(x.shape[1]),
                                 interpret=_INTERPRET)
        sums.append(acc[0, :_flat(l).shape[1]].reshape(l.shape[1:]))
    norms = jnp.sqrt(jnp.maximum(sq, 0.0)).reshape(-1)
    return jax.tree.unflatten(treedef, sums), norms
