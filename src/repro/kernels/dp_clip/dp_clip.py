"""Per-example gradient clip + accumulate — Pallas TPU kernels (DP-SGD).

DP-SGD's hot path is, for a batch of per-example gradients g_1..g_B:

    s_b   = min(1, C / ||g_b||_2)          (per-example clip factor)
    G     = sum_b s_b * g_b                (clipped sum, then noise+mean)

Materializing the CLIPPED per-example gradient tree costs another B x |params|
of HBM.  These kernels keep the reduction on-chip: per-example squared norms
are accumulated across feature blocks in a single VMEM pass, and the clipped
sum is a scale-fused batch reduction that writes only the (D,) accumulator —
the scaled per-example gradients never exist in HBM.

Tiling: both kernels grid over feature blocks of the (B, D) per-example
gradient matrix (leaves are flattened and processed leaf-by-leaf by the ops
layer so cross-leaf norms compose).  ``sqnorm`` accumulates into a (B, 1)
column across grid steps ("arbitrary" semantics — same revisiting-output
pattern as flash-attention's softmax state); ``scale_accum`` reduces the
batch axis per feature block ("parallel" — blocks are independent).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import INTERPRET, CompilerParams


def _sqnorm_kernel(g_ref, out_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
    g = g_ref[...].astype(jnp.float32)
    out_ref[...] += jnp.sum(g * g, axis=-1, keepdims=True)


def _scale_accum_kernel(g_ref, s_ref, out_ref):
    g = g_ref[...].astype(jnp.float32)
    out_ref[...] = jnp.sum(g * s_ref[...], axis=0, keepdims=True)


def sqnorms_pallas(g, *, block_d=512, interpret=INTERPRET):
    """g: (B, D) -> (B, 1) f32 per-example sums of squares."""
    b, d = g.shape
    block_d = min(block_d, d)
    assert d % block_d == 0
    grid = (d // block_d,)
    return pl.pallas_call(
        _sqnorm_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((b, block_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((b, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(g)


def scale_accum_pallas(g, scales, *, block_d=512, interpret=INTERPRET):
    """g: (B, D), scales: (B, 1) -> (1, D) f32 of sum_b scales[b] * g[b]."""
    b, d = g.shape
    block_d = min(block_d, d)
    assert d % block_d == 0
    grid = (d // block_d,)
    return pl.pallas_call(
        _scale_accum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((b, block_d), lambda i: (0, i)),
                  pl.BlockSpec((b, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(g, scales)
