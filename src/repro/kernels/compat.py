"""Pallas-TPU API compatibility across jax versions.

``pltpu.TPUCompilerParams`` (jax <= 0.4.x) was renamed to
``pltpu.CompilerParams`` in later releases; resolve whichever exists once.

``INTERPRET`` is the shared interpret-mode default for every kernel's ops
layer: off-TPU backends (CPU CI, GPU dev boxes) run the kernels through the
Pallas interpreter so the whole suite stays runnable anywhere.
"""

from __future__ import annotations

import jax
import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

INTERPRET = jax.default_backend() != "tpu"
