"""Pallas-TPU API compatibility across jax versions.

``pltpu.TPUCompilerParams`` (jax <= 0.4.x) was renamed to
``pltpu.CompilerParams`` in later releases; resolve whichever exists once.
"""

from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
