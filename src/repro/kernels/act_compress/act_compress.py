"""Cut-layer activation compression — Pallas TPU kernel (beyond-paper).

The paper's headline infeasibility result is SL/SFL communication volume
(774 GB/epoch for U-Net label-sharing).  This kernel fuses per-row absmax
int8 quantization of the cut-layer activations so the bytes crossing the
client<->server boundary shrink ~4x (bf16 -> int8 + 1 fp32 scale per row)
in a single VMEM pass — no extra HBM round-trip for the absmax.

Tiling: grid over row blocks; each cell reads a (block_rows, D) tile into
VMEM, reduces |x| over D (VPU), scales, rounds and writes the int8 tile
plus the (block_rows, 1) scale column.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import INTERPRET, CompilerParams


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]).astype(
        o_ref.dtype)


def quantize_pallas(x, *, block_rows=256, interpret=INTERPRET):
    """x: (T, D) -> (int8 (T, D), f32 scale (T, 1))."""
    t, d = x.shape
    block_rows = min(block_rows, t)
    assert t % block_rows == 0
    grid = (t // block_rows,)
    return pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((t, d), jnp.int8),
                   jax.ShapeDtypeStruct((t, 1), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x)


def dequantize_pallas(q, scale, dtype=jnp.bfloat16, *, block_rows=256,
                      interpret=INTERPRET):
    t, d = q.shape
    block_rows = min(block_rows, t)
    assert t % block_rows == 0
    grid = (t // block_rows,)
    return pl.pallas_call(
        _dequant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, d), dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(q, scale)
