"""Oracle for the cut-layer activation compressor (per-row int8)."""

from __future__ import annotations

import jax.numpy as jnp


def quantize_ref(x):
    """x: (T, D) -> (q int8 (T, D), scale f32 (T, 1))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_ref(q, scale, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def roundtrip_ref(x):
    q, s = quantize_ref(x)
    return dequantize_ref(q, s, x.dtype)
