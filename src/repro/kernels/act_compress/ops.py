"""Jitted wrappers + straight-through-estimator roundtrip for training.

``compress_boundary`` is applied at the SL/SFL cut layer: forward passes the
int8-roundtripped activation (what the server actually receives over the
wire); the backward pass is identity (STE), matching deployments that
quantize the link but keep full-precision gradients client-side.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.act_compress.act_compress import (dequantize_pallas,
                                                     quantize_pallas)
from repro.kernels.compat import INTERPRET as _INTERPRET


@jax.jit
def quantize(x):
    """x: (..., D) -> (int8 same shape, f32 scales (..., 1))."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    block = min(256, max(8, x2.shape[0]))
    pad = (-x2.shape[0]) % block
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    q, s = quantize_pallas(x2, block_rows=block, interpret=_INTERPRET)
    import math
    n = math.prod(shape[:-1])
    return (q[:n].reshape(shape),
            s[:n].reshape(shape[:-1] + (1,)))


@partial(jax.jit, static_argnames=("dtype",))
def dequantize(q, s, dtype=jnp.bfloat16):
    shape = q.shape
    q2 = q.reshape(-1, shape[-1])
    s2 = s.reshape(-1, 1)
    block = min(256, max(8, q2.shape[0]))
    pad = (-q2.shape[0]) % block
    if pad:
        q2 = jnp.pad(q2, ((0, pad), (0, 0)))
        s2 = jnp.pad(s2, ((0, pad), (0, 0)))
    out = dequantize_pallas(q2, s2, dtype, block_rows=block,
                            interpret=_INTERPRET)
    import math
    n = math.prod(shape[:-1])
    return out[:n].reshape(shape)


@jax.custom_vjp
def compress_boundary(x):
    q, s = quantize(x)
    return dequantize(q, s, x.dtype)


def _fwd(x):
    return compress_boundary(x), None


def _bwd(_, g):
    return (g,)       # straight-through


compress_boundary.defvjp(_fwd, _bwd)
