"""Oracle for the SSD kernel: the pure-jnp chunked SSD from the model layer."""

from repro.models.mamba import ssd_chunked


def ssd_ref(x, dt, A, B, C, chunk):
    """x: (b,l,h,p)  dt: (b,l,h)  A: (h,)  B,C: (b,l,g,n).
    Returns (y (b,l,h,p), final_state (b,h,p,n))."""
    return ssd_chunked(x, dt, A, B, C, chunk)
