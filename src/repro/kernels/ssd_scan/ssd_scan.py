"""Mamba2 SSD — Pallas TPU kernel for the chunk-local compute.

TPU adaptation of the SSD algorithm (arXiv:2405.21060): the chunk-quadratic
term and the per-chunk state summaries are MXU matmuls per (batch, chunk,
head) grid cell, all operands tiled into VMEM; the strictly-sequential
inter-chunk recurrence (a tiny (h,p,n) scan) and the rank-1 inter-chunk
output correction stay outside in XLA, where a `lax.scan` over nc steps is
already optimal (it is bandwidth-trivial next to the chunk matmuls).

Per grid cell (b, c, h):
  xbar tile : (q, p)   VMEM     (dt-discretized inputs)
  B/C tile  : (q, n)   VMEM     (GQA-style group indexing h -> h // rep)
  la tile   : (q, 1)   VMEM     (log decay, fp32)
  outputs   : y_intra (q, p), state (n, p), decay vectors (q, 1)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(xbar_ref, la_ref, b_ref, c_ref, y_ref, st_ref, dte_ref, dfs_ref):
    xbar = xbar_ref[0, 0, :, 0, :].astype(jnp.float32)      # (q, p)
    la = la_ref[0, 0, :, :].astype(jnp.float32)             # (q, 1)
    B = b_ref[0, 0, :, 0, :].astype(jnp.float32)            # (q, n)
    C = c_ref[0, 0, :, 0, :].astype(jnp.float32)            # (q, n)
    q = xbar.shape[0]

    cs = jnp.cumsum(la, axis=0)                             # (q, 1) inclusive
    # L[i, j] = exp(cs_i - cs_j) for j <= i else 0
    seg = cs - cs.reshape(1, q)                             # (q, q)
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    lmat = jnp.where(cols <= rows, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (q,q)
    y = jax.lax.dot_general(scores * lmat, xbar,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)       # (q,p)

    total = cs[q - 1:q, :]                                  # (1, 1)
    dte = jnp.exp(total - cs)                               # (q, 1) to-end
    dfs = jnp.exp(cs)                                       # (q, 1) from-start
    state = jax.lax.dot_general(B * dte, xbar,
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)   # (n,p)

    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)
    st_ref[0, 0, 0, :, :] = state
    dte_ref[0, 0, :, :] = dte
    dfs_ref[0, 0, :, :] = dfs


def ssd_chunk_kernel(xbar, la, B, C, *, interpret=True):
    """xbar: (b, nc, q, h, p)  la: (b, nc, q, h)  B, C: (b, nc, q, g, n).
    Returns y_intra (b,nc,q,h,p) f32, states (b,nc,h,n,p) f32,
            dte (b,nc,q,h) f32, dfs (b,nc,q,h) f32."""
    b, nc, q, h, p = xbar.shape
    g, n = B.shape[3], B.shape[4]
    rep = h // g
    grid = (b, nc, h)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q, 1, p),
                         lambda bb, cc, hh: (bb, cc, 0, hh, 0)),
            pl.BlockSpec((1, 1, q, 1),
                         lambda bb, cc, hh: (bb, cc, 0, hh)),
            pl.BlockSpec((1, 1, q, 1, n),
                         lambda bb, cc, hh: (bb, cc, 0, hh // rep, 0)),
            pl.BlockSpec((1, 1, q, 1, n),
                         lambda bb, cc, hh: (bb, cc, 0, hh // rep, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, 1, p),
                         lambda bb, cc, hh: (bb, cc, 0, hh, 0)),
            pl.BlockSpec((1, 1, 1, n, p),
                         lambda bb, cc, hh: (bb, cc, hh, 0, 0)),
            pl.BlockSpec((1, 1, q, 1),
                         lambda bb, cc, hh: (bb, cc, 0, hh)),
            pl.BlockSpec((1, 1, q, 1),
                         lambda bb, cc, hh: (bb, cc, 0, hh)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h, n, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, q, h), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, q, h), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(xbar, la, B, C)
    return out
