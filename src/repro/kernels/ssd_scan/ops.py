"""Jitted SSD wrapper: Pallas chunk kernel + XLA inter-chunk recurrence."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_chunk_kernel

_INTERPRET = jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("chunk",))
def ssd(x, dt, A, B, C, chunk):
    """x: (b,l,h,p)  dt: (b,l,h) (post-softplus)  A: (h,) positive
    B, C: (b,l,g,n).  Returns (y (b,l,h,p), final_state (b,h,p,n))."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert l % chunk == 0
    nc, q = l // chunk, chunk
    rep = h // g

    xbar = (x * dt[..., None]).reshape(b, nc, q, h, p)
    la = (-dt * A).astype(jnp.float32).reshape(b, nc, q, h)
    Bc = B.reshape(b, nc, q, g, n)
    Cc = C.reshape(b, nc, q, g, n)

    y_intra, states, dte, dfs = ssd_chunk_kernel(
        xbar, la, Bc, Cc, interpret=_INTERPRET)

    # inter-chunk recurrence (sequential over nc, tiny (h,n,p) carry)
    a_last = jnp.exp(la.sum(axis=2))                        # (b, nc, h)

    def body(s, inp):
        st, al = inp
        s_new = s * al[:, :, None, None] + st
        return s_new, s

    s0 = jnp.zeros((b, h, n, p), jnp.float32)
    final, prev = jax.lax.scan(
        body, s0, (states.transpose(1, 0, 2, 3, 4),
                   a_last.transpose(1, 0, 2)))
    prev = prev.transpose(1, 0, 2, 3, 4)                    # (b,nc,h,n,p)

    Crep = jnp.repeat(Cc, rep, axis=3).astype(jnp.float32)  # (b,nc,q,h,n)
    y_inter = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp", Crep, prev, dfs)
    y = (y_intra + y_inter).reshape(b, l, h, p).astype(x.dtype)
    return y, final.transpose(0, 1, 3, 2)                   # (b,h,p,n)
