"""Jitted public wrapper for the flash-attention kernel.

Accepts the model-layer layout (B, S, H, D)/(B, S, KV, D) and handles the
transpose to the kernel's (B, H, S, D) layout.  On CPU the kernel runs in
interpret mode (the TPU target compiles the same kernel body).
"""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas

_INTERPRET = jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, causal=True, block_q=128, block_k=128):
    """q: (B, S, H, D); k, v: (B, S, KV, D).  Returns (B, S, H, D)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_pallas(qt, kt, vt, causal=causal, block_q=block_q,
                                 block_k=block_k, interpret=_INTERPRET)
    return out.transpose(0, 2, 1, 3)
