"""Flash attention for TPU (Pallas): block-wise online softmax.

Tiling (per DESIGN.md §6): the grid is (batch, q_head, q_block, kv_block);
the kv_block axis is the innermost, sequentially-iterated ("arbitrary")
dimension, accumulating into VMEM scratch:

  q tile   : (block_q, d)        VMEM
  k/v tile : (block_k, d)        VMEM (indexed by the GQA group of the head)
  acc      : (block_q, d)  fp32  VMEM scratch
  m, l     : (block_q, 128) fp32 VMEM scratch (lane-replicated running max/sum)

block_q/block_k default to 128 — MXU-aligned (128x128 systolic array) and
8-lane friendly.  Causal masking skips fully-masked kv blocks via
``pl.when`` on the block indices (structural win: ~2x for causal prefill).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.compat import CompilerParams

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale, causal, block_q, block_k, seq_q, seq_k):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: kv block strictly after the q block is fully masked -> skip.
    # (kv positions are offset by seq_k - seq_q when kv is longer, e.g. a
    #  prefilled cache; here seq_q == seq_k for the training/prefill path.)
    q_start = qi * block_q
    k_start = ki * block_k
    should_run = jnp.logical_or(
        jnp.logical_not(causal), k_start <= q_start + block_q - 1)

    @pl.when(should_run)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, d)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            logits = jnp.where(cols <= rows, logits, NEG_INF)

        m_prev = m_ref[:, :1]                          # (bq, 1)
        m_cur = logits.max(axis=-1, keepdims=True)     # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(logits - m_new)                    # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                 # (bq, 1)
        l_new = l_ref[:, :1] * corr + p.sum(-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_ref[:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(
            o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal=True, block_q=128, block_k=128,
                           interpret=True):
    """q: (B, H, S, D); k, v: (B, KV, S, D).  Returns (B, H, S, D)."""
    b, h, s, d = q.shape
    kv, t = k.shape[1], k.shape[2]
    assert h % kv == 0 and t == s, (q.shape, k.shape)
    rep = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, t)
    assert s % block_q == 0 and t % block_k == 0
    grid = (b, h, s // block_q, t // block_k)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, seq_q=s, seq_k=t)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, qi, ki: (bb, hh // rep, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, qi, ki: (bb, hh // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
