"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import math

import jax.numpy as jnp
import jax


def flash_attention_ref(q, k, v, causal=True):
    """q: (B, H, S, D); k, v: (B, KV, T, D) with H % KV == 0.
    Returns (B, H, S, D).  fp32 softmax, output in q.dtype."""
    b, h, s, d = q.shape
    kv, t = k.shape[1], k.shape[2]
    rep = h // kv
    qg = q.reshape(b, kv, rep, s, d).astype(jnp.float32)
    logits = jnp.einsum("bgrsd,bgtd->bgrst", qg,
                        k.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        mask = jnp.arange(t)[None, :] <= jnp.arange(s)[:, None] + (t - s)
        logits = jnp.where(mask, logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bgrst,bgtd->bgrsd", w, v.astype(jnp.float32))
    return out.reshape(b, h, s, d).astype(q.dtype)
