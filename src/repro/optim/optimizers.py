from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]   # (grads, state, params) -> (updates, state)


def _lr_at(lr, step):
    return lr(step) if callable(lr) else lr


def sgd(lr, momentum: float = 0.0) -> Optimizer:
    def init(params):
        mom = jax.tree.map(jnp.zeros_like, params) if momentum else None
        return {"step": jnp.zeros((), jnp.int32), "mom": mom}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        if momentum:
            mom = jax.tree.map(lambda m, g: momentum * m + g,
                               state["mom"], grads)
            upd = jax.tree.map(lambda m: -lr_t * m, mom)
            return upd, {"step": step, "mom": mom}
        return jax.tree.map(lambda g: -lr_t * g, grads), {"step": step,
                                                          "mom": None}

    return Optimizer(init, update)


def adam(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
         state_dtype=jnp.float32) -> Optimizer:
    """Adam / AdamW (paper §3.2: Adam, b1=.9, b2=.999, lr=1e-4).
    ``state_dtype=bf16`` halves optimizer HBM for the giant configs."""
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(lambda p: jnp.zeros_like(p, state_dtype),
                                   params),
                "nu": jax.tree.map(lambda p: jnp.zeros_like(p, state_dtype),
                                   params)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = _lr_at(lr, step)
        mu = jax.tree.map(lambda m, g: (b1 * m.astype(jnp.float32) + (1 - b1)
                          * g.astype(jnp.float32)).astype(m.dtype),
                          state["mu"], grads)
        nu = jax.tree.map(lambda v, g: (b2 * v.astype(jnp.float32) + (1 - b2)
                          * jnp.square(g.astype(jnp.float32))).astype(v.dtype),
                          state["nu"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m, v, p):
            m, v = m.astype(jnp.float32), v.astype(jnp.float32)
            u = -lr_t * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        updates = (jax.tree.map(upd, mu, nu, params) if params is not None
                   else jax.tree.map(lambda m, v: upd(m, v, None), mu, nu))
        return updates, {"step": step, "mu": mu, "nu": nu}

    return Optimizer(init, update)


def clip_by_global_norm(max_norm: float) -> Optimizer:
    def init(params):
        return {}

    def update(grads, state, params=None):
        norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                            for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
        return jax.tree.map(lambda g: g * scale, grads), state

    return Optimizer(init, update)


def tree_gaussian_noise(tree, key, std: float):
    """``tree + N(0, std^2)`` leaf-wise, one key split per leaf, original
    leaf dtypes preserved.  Shared by ``add_noise`` and repro.privacy."""
    if std <= 0:
        return tree
    leaves, treedef = jax.tree.flatten(tree)
    ks = jax.random.split(key, len(leaves))
    noised = [l + (std * jax.random.normal(k, l.shape,
                                           jnp.float32)).astype(l.dtype)
              for l, k in zip(leaves, ks)]
    return jax.tree.unflatten(treedef, noised)


def add_noise(std: float, seed: int = 0) -> Optimizer:
    """Additive iid Gaussian gradient noise (``chain`` AFTER clipping for a
    DP-style update rule; repro.privacy's per-example DP-SGD noises the
    clipped SUM instead — this transform is the coarse batch-level cousin).
    The PRNG key lives in the optimizer state and advances every update."""
    def init(params):
        return {"key": jax.random.key(seed)}

    def update(grads, state, params=None):
        if std <= 0:
            return grads, state
        key, sub = jax.random.split(state["key"])
        return tree_gaussian_noise(grads, sub, std), {"key": key}

    return Optimizer(init, update)


def chain(*opts: Optimizer) -> Optimizer:
    def init(params):
        return tuple(o.init(params) for o in opts)

    def update(grads, state, params=None):
        new_state = []
        for o, s in zip(opts, state):
            grads, s = o.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)
