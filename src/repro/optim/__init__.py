"""Self-contained optimizers + LR schedules (no optax dependency).

API mirrors optax: ``opt = adam(1e-4); state = opt.init(params);
updates, state = opt.update(grads, state, params); params = apply_updates(...)``.
"""

from repro.optim.optimizers import (adam, add_noise, sgd, apply_updates,
                                    clip_by_global_norm, chain, Optimizer,
                                    tree_gaussian_noise)
from repro.optim.schedules import constant, cosine_warmup, wsd

__all__ = ["adam", "add_noise", "sgd", "apply_updates",
           "clip_by_global_norm", "chain", "Optimizer",
           "tree_gaussian_noise", "constant", "cosine_warmup", "wsd"]
