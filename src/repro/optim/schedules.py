"""LR schedules, including WSD (warmup-stable-decay) from MiniCPM
[arXiv:2404.06395], one of the assigned architectures."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_warmup(peak: float, warmup: int, total: int, floor: float = 0.0):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return fn


def wsd(peak: float, warmup: int, stable: int, decay: int,
        floor_frac: float = 0.1):
    """Warmup -> Stable (constant) -> exponential Decay (MiniCPM §4)."""
    floor = peak * floor_frac

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup - stable) / jnp.maximum(decay, 1), 0, 1)
        dec = peak * (floor / peak) ** frac
        return jnp.where(step < warmup, warm,
                         jnp.where(step < warmup + stable, peak, dec))
    return fn
