"""Minimal msgpack pytree checkpointing (no orbax dependency)."""

from __future__ import annotations

import os

import jax
import msgpack
import numpy as np


def _flatten(tree):
    # jax.tree.flatten_with_path only exists on newer jax; tree_util always has it
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save(path: str, tree) -> None:
    flat, _ = _flatten(tree)
    payload = {k: {"dtype": str(v.dtype), "shape": list(v.shape),
                   "data": v.tobytes()} for k, v in flat.items()}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))


def load(path: str, like):
    """Restore into the structure of ``like`` (names must match)."""
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    flat_like, treedef = _flatten(like)
    leaves = []
    for key, ref in flat_like.items():
        rec = payload[key]
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"]))
        leaves.append(arr.reshape(rec["shape"]))
    return jax.tree.unflatten(treedef, leaves)
