"""Threshold-free and threshold metrics used by the paper (§3.6):
AUROC, AUPRC, F1-score, Cohen's kappa.  Pure numpy (evaluation-time)."""

from __future__ import annotations

import numpy as np


def auroc(labels, scores) -> float:
    """Rank-based (Mann-Whitney) AUROC, tie-aware."""
    labels = np.asarray(labels).astype(bool).ravel()
    scores = np.asarray(scores, np.float64).ravel()
    n_pos, n_neg = labels.sum(), (~labels).sum()
    if n_pos == 0 or n_neg == 0:
        return float("nan")
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    sorted_scores = scores[order]
    i = 0
    r = 1.0
    while i < len(sorted_scores):
        j = i
        while j + 1 < len(sorted_scores) and sorted_scores[j + 1] == sorted_scores[i]:
            j += 1
        ranks[order[i:j + 1]] = 0.5 * ((i + 1) + (j + 1))
        i = j + 1
    return float((ranks[labels].sum() - n_pos * (n_pos + 1) / 2)
                 / (n_pos * n_neg))


def auprc(labels, scores) -> float:
    """Average precision (step-wise integration of the PR curve).

    Tied scores are integrated as ONE threshold group (sklearn's
    convention), so the value is invariant to the input ordering of ties.
    """
    labels = np.asarray(labels).astype(bool).ravel()
    scores = np.asarray(scores, np.float64).ravel()
    n_pos = labels.sum()
    if n_pos == 0:
        return float("nan")
    order = np.argsort(-scores, kind="mergesort")
    lab, s = labels[order], scores[order]
    ends = np.append(np.where(np.diff(s))[0], len(s) - 1)   # group ends
    tp = np.cumsum(lab)[ends]
    precision = tp / (ends + 1.0)
    recall_delta = np.diff(np.concatenate([[0], tp])) / n_pos
    return float((precision * recall_delta).sum())


def confusion(labels, scores, threshold=0.5):
    labels = np.asarray(labels).astype(bool).ravel()
    pred = np.asarray(scores).ravel() >= threshold
    tp = int((pred & labels).sum())
    fp = int((pred & ~labels).sum())
    fn = int((~pred & labels).sum())
    tn = int((~pred & ~labels).sum())
    return tp, fp, fn, tn


def f1_score(labels, scores, threshold=0.5) -> float:
    tp, fp, fn, _ = confusion(labels, scores, threshold)
    denom = 2 * tp + fp + fn
    return float(2 * tp / denom) if denom else float("nan")


def kappa(labels, scores, threshold=0.5) -> float:
    tp, fp, fn, tn = confusion(labels, scores, threshold)
    n = tp + fp + fn + tn
    po = (tp + tn) / n
    pe = ((tp + fp) * (tp + fn) + (fn + tn) * (fp + tn)) / (n * n)
    return float((po - pe) / (1 - pe)) if pe < 1 else float("nan")


def sensitivity(labels, scores, threshold=0.5) -> float:
    """True positive rate (recall) — the screening-critical number."""
    tp, _, fn, _ = confusion(labels, scores, threshold)
    return float(tp / (tp + fn)) if tp + fn else float("nan")


def specificity(labels, scores, threshold=0.5) -> float:
    """True negative rate."""
    _, fp, _, tn = confusion(labels, scores, threshold)
    return float(tn / (tn + fp)) if tn + fp else float("nan")


def expected_calibration_error(labels, scores, n_bins=10) -> float:
    """ECE: confidence-weighted |accuracy - confidence| over equal-width
    probability bins (Guo et al. 2017, binary form on P(y=1))."""
    labels = np.asarray(labels).astype(np.float64).ravel()
    scores = np.asarray(scores, np.float64).ravel()
    if len(labels) == 0:
        return float("nan")
    bins = np.clip((scores * n_bins).astype(int), 0, n_bins - 1)
    ece = 0.0
    for b in range(n_bins):
        sel = bins == b
        if not sel.any():
            continue
        ece += sel.mean() * abs(labels[sel].mean() - scores[sel].mean())
    return float(ece)


def all_metrics(labels, scores, threshold=0.5) -> dict:
    return {"auroc": auroc(labels, scores),
            "auprc": auprc(labels, scores),
            "f1": f1_score(labels, scores, threshold),
            "kappa": kappa(labels, scores, threshold),
            "sensitivity": sensitivity(labels, scores, threshold),
            "specificity": specificity(labels, scores, threshold),
            "ece": expected_calibration_error(labels, scores)}
