"""Mamba2-130M — SSD (state-space duality), attention-free [arXiv:2405.21060]."""

from repro.configs.base import ArchEntry, _ALL
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", arch_type="ssm",
    n_layers=24, d_model=768, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_head_dim=64, ssm_chunk=128,
    cut_layer=4, source="arXiv:2405.21060",
)

SMOKE = ModelConfig(
    name="mamba2-smoke", arch_type="ssm",
    n_layers=2, d_model=128, d_ff=0, vocab_size=512,
    ssm_state=16, ssm_head_dim=32, ssm_chunk=8,
    cut_layer=1, remat=False, source="arXiv:2405.21060",
)

ENTRY = ArchEntry(
    arch_id="mamba2-130m", config=CONFIG, smoke=SMOKE, shapes=_ALL,
    skip_notes="runs long_500k: attention-free, O(1) state per token.")
