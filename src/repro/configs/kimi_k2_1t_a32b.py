"""Kimi K2 — trillion-param MoE (paper-table) [arXiv:2501.kimi2]."""

from repro.configs.base import ArchEntry, _FULL
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", arch_type="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab_size=163840, head_dim=112,
    n_experts=384, top_k=8, first_k_dense=1, n_shared_experts=1,
    capacity_factor=1.0, moe_chunk=512, chunk_kv=2048,
    # client keeps embed + the single dense layer (MoE stays server-side)
    cut_layer=1, source="arXiv:2501.kimi2",
)

SMOKE = ModelConfig(
    name="kimi-k2-smoke", arch_type="moe",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, head_dim=64,
    n_experts=4, top_k=2, first_k_dense=1, n_shared_experts=1,
    moe_chunk=64, cut_layer=1, remat=False, source="arXiv:2501.kimi2",
)

ENTRY = ArchEntry(
    arch_id="kimi-k2-1t-a32b", config=CONFIG, smoke=SMOKE, shapes=_FULL,
    skip_notes="long_500k skipped: full quadratic attention (no "
               "sliding-window variant published for K2).")
