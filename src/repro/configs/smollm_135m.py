"""SmolLM-135M — llama-arch small [hf:HuggingFaceTB/SmolLM-135M]."""

from repro.configs.base import ArchEntry, _FULL
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m", arch_type="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_ff=1536,
    vocab_size=49152, head_dim=64, rope_theta=10000.0, chunk_kv=2048,
    cut_layer=4, source="hf:HuggingFaceTB/SmolLM-135M",
)

SMOKE = ModelConfig(
    name="smollm-smoke", arch_type="dense",
    n_layers=2, d_model=192, n_heads=3, n_kv_heads=3, d_ff=512,
    vocab_size=512, head_dim=64, cut_layer=1, remat=False,
    source="hf:HuggingFaceTB/SmolLM-135M",
)

ENTRY = ArchEntry(
    arch_id="smollm-135m", config=CONFIG, smoke=SMOKE, shapes=_FULL,
    skip_notes="long_500k skipped: full quadratic attention.")
