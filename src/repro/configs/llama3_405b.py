"""Llama-3 405B — dense GQA, 128k vocab [arXiv:2407.21783]."""

from repro.configs.base import ArchEntry, _FULL
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", arch_type="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_ff=53248,
    vocab_size=128256, head_dim=128, rope_theta=500000.0, chunk_kv=2048,
    cut_layer=2, source="arXiv:2407.21783",
)

SMOKE = ModelConfig(
    name="llama3-smoke", arch_type="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, d_ff=832,
    vocab_size=512, head_dim=32, rope_theta=500000.0,
    cut_layer=1, remat=False, source="arXiv:2407.21783",
)

ENTRY = ArchEntry(
    arch_id="llama3-405b", config=CONFIG, smoke=SMOKE, shapes=_FULL,
    skip_notes="long_500k skipped: full quadratic attention (paper model); "
               "see llama4-scout for the sliding-window dense variant.")
