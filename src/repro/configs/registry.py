"""--arch <id> registry over the 10 assigned architectures."""

from __future__ import annotations

from repro.configs import (kimi_k2_1t_a32b, musicgen_medium, internvl2_76b,
                           minicpm_2b, llama3_405b, zamba2_7b, smollm_135m,
                           mistral_large_123b, llama4_scout_17b_a16e,
                           mamba2_130m)
from repro.configs.base import ArchEntry, INPUT_SHAPES

_MODULES = [kimi_k2_1t_a32b, musicgen_medium, internvl2_76b, minicpm_2b,
            llama3_405b, zamba2_7b, smollm_135m, mistral_large_123b,
            llama4_scout_17b_a16e, mamba2_130m]

REGISTRY: dict[str, ArchEntry] = {m.ENTRY.arch_id: m.ENTRY for m in _MODULES}

ARCH_IDS = list(REGISTRY)


def get(arch_id: str) -> ArchEntry:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown --arch {arch_id!r}; known: {ARCH_IDS}")
    return REGISTRY[arch_id]


def combos():
    """All (arch_id, shape_name) pairs the dry-run must lower (40 total,
    with long_500k included only where the arch qualifies)."""
    out = []
    for aid, e in REGISTRY.items():
        for shape in INPUT_SHAPES:
            out.append((aid, shape, shape in e.shapes))
    return out
