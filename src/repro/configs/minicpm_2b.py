"""MiniCPM-2B — llama-like dense with the WSD schedule [arXiv:2404.06395].
The WSD (warmup-stable-decay) schedule itself lives in
repro.optim.schedules.wsd and is wired in the train launcher for this arch.
"""

from repro.configs.base import ArchEntry, _FULL
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", arch_type="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760,
    vocab_size=122753, head_dim=64, rope_theta=10000.0, chunk_kv=2048,
    cut_layer=4, source="arXiv:2404.06395",
)

SMOKE = ModelConfig(
    name="minicpm-smoke", arch_type="dense",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=640,
    vocab_size=503,   # deliberately odd, like the parent's 122753
    cut_layer=1, remat=False, source="arXiv:2404.06395",
)

ENTRY = ArchEntry(
    arch_id="minicpm-2b", config=CONFIG, smoke=SMOKE, shapes=_FULL,
    skip_notes="long_500k skipped: full quadratic attention.")
