"""Llama-4 Scout 17B-active / 16 experts — MoE, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E].

Scout interleaves chunked (8192-window) attention on most layers — modeled
here as sliding_window=8192, which is what qualifies this dense-attention
MoE for the long_500k decode shape (each step attends to at most 8192 keys).
"""

from repro.configs.base import ArchEntry, _ALL
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", arch_type="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab_size=202048, head_dim=128, rope_theta=500000.0,
    sliding_window=8192, chunk_kv=2048,
    n_experts=16, top_k=1, n_shared_experts=1, capacity_factor=1.25,
    moe_chunk=512, cut_layer=4,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke", arch_type="moe",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=256,
    vocab_size=512, head_dim=64, sliding_window=64,
    n_experts=4, top_k=1, n_shared_experts=1, moe_chunk=64,
    cut_layer=1, remat=False,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)

ENTRY = ArchEntry(
    arch_id="llama4-scout-17b-a16e", config=CONFIG, smoke=SMOKE, shapes=_ALL,
    skip_notes="runs long_500k via the 8192 sliding/chunked attention "
               "window (decode touches a bounded KV slice per step).")
