"""Zamba2-7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

81 Mamba2 layers with one SHARED attention+MLP block applied every 6 layers
(one param set, several depths — zamba2's signature trick), ssm_state=64.
"""

from repro.configs.base import ArchEntry, _ALL
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", arch_type="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab_size=32000, head_dim=112, chunk_kv=2048,
    ssm_state=64, ssm_head_dim=64, ssm_chunk=128,
    hybrid_attn_every=6,
    cut_layer=4, source="arXiv:2411.15242",
)

SMOKE = ModelConfig(
    name="zamba2-smoke", arch_type="hybrid",
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=512, head_dim=32, ssm_state=16, ssm_head_dim=32, ssm_chunk=8,
    hybrid_attn_every=2, cut_layer=2, remat=False,
    source="arXiv:2411.15242",
)

ENTRY = ArchEntry(
    arch_id="zamba2-7b", config=CONFIG, smoke=SMOKE, shapes=_ALL,
    skip_notes="runs long_500k: SSM layers are O(1)/token; the shared "
               "attention blocks attend over the full 512k KV cache during "
               "decode (memory-bound, linear per step).")
