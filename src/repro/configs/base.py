"""Config registry plumbing.

Every assigned architecture ships as ``src/repro/configs/<id>.py`` exposing:
  CONFIG — the exact published configuration (sources cited in `source`)
  SMOKE  — a reduced same-family variant (<=2-ish layers, d_model<=512,
           <=4 experts) used by the CPU smoke tests.
"""

from __future__ import annotations

import dataclasses

from repro.models.transformer import ModelConfig

# input shapes assigned to this paper (see the assignment block)
INPUT_SHAPES = {
    "train_4k":    {"seq_len": 4096,   "global_batch": 256, "kind": "train"},
    "prefill_32k": {"seq_len": 32768,  "global_batch": 32,  "kind": "prefill"},
    "decode_32k":  {"seq_len": 32768,  "global_batch": 128, "kind": "decode"},
    "long_500k":   {"seq_len": 524288, "global_batch": 1,   "kind": "decode"},
}


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    config: ModelConfig
    smoke: ModelConfig
    shapes: tuple[str, ...]          # which INPUT_SHAPES this arch runs
    skip_notes: str = ""             # why any shape is skipped (DESIGN.md)


_FULL = ("train_4k", "prefill_32k", "decode_32k")
_ALL = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
