"""InternVL2-76B — InternViT + InternLM2 backbone [arXiv:2404.16821].

The InternViT-6B vision tower is the stubbed frontend (hidden 3200);
input_specs() provides projected patch embeddings.  The LM backbone below is
the InternLM2-72B-ish decoder the assignment specifies.
"""

from repro.configs.base import ArchEntry, _FULL
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", arch_type="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab_size=128256, head_dim=128, chunk_kv=2048,
    frontend="vision", frontend_dim=3200, frontend_tokens=256,
    cut_layer=2, source="arXiv:2404.16821",
)

SMOKE = ModelConfig(
    name="internvl2-smoke", arch_type="vlm",
    n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
    vocab_size=512, frontend="vision", frontend_dim=64, frontend_tokens=8,
    cut_layer=1, remat=False, source="arXiv:2404.16821",
)

ENTRY = ArchEntry(
    arch_id="internvl2-76b", config=CONFIG, smoke=SMOKE, shapes=_FULL,
    skip_notes="long_500k skipped: full quadratic attention.")
