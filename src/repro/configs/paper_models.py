"""The paper's own two model families (§3.2):
DenseNet-121 @224^2 and U-Net (Xception-flavoured) @768^2, plus the reduced
"mini" variants used for the CPU reproduction experiments.
"""

from repro.models.cnn import DenseNetConfig, UNetConfig

DENSENET121_PAPER = DenseNetConfig(
    name="densenet121-paper", growth=32, blocks=(6, 12, 24, 16), stem_ch=64,
    in_ch=1, n_classes=1, cut_layer=4)       # paper: first 4 layers at client

UNET_PAPER = UNetConfig(
    name="unet-xception-paper", widths=(64, 128, 256, 512, 728), in_ch=1,
    n_classes=1, cut_layer=6)                # paper: first 6 layers at client

# reduced variants for the CPU reproduction run (orderings, not absolutes)
DENSENET_MINI = DenseNetConfig(
    name="densenet-mini", growth=12, blocks=(3, 6, 8), stem_ch=24,
    in_ch=1, n_classes=1, cut_layer=3)

UNET_MINI = UNetConfig(
    name="unet-mini", widths=(16, 32, 64, 96), in_ch=1, n_classes=1,
    cut_layer=2)
