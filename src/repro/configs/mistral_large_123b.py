"""Mistral-Large-2 123B [hf:mistralai/Mistral-Large-Instruct-2407]."""

from repro.configs.base import ArchEntry, _FULL
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b", arch_type="dense",
    n_layers=88, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=28672,
    vocab_size=32768, head_dim=128, rope_theta=1000000.0, chunk_kv=2048,
    cut_layer=2, source="hf:mistralai/Mistral-Large-Instruct-2407",
)

SMOKE = ModelConfig(
    name="mistral-large-smoke", arch_type="dense",
    n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, d_ff=640,
    vocab_size=512, head_dim=32, cut_layer=1, remat=False,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)

ENTRY = ArchEntry(
    arch_id="mistral-large-123b", config=CONFIG, smoke=SMOKE, shapes=_FULL,
    skip_notes="long_500k skipped: full quadratic attention.")
