"""MusicGen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284].

The EnCodec conv codec is the stubbed modality frontend: input_specs()
provides precomputed frame embeddings (conditioning prefix) plus the audio
token stream over the 2048-entry codebook vocabulary.
"""

from repro.configs.base import ArchEntry, _FULL
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", arch_type="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144,
    vocab_size=2048, rope_theta=10000.0, chunk_kv=2048,
    frontend="audio", frontend_dim=128, frontend_tokens=256,
    cut_layer=4, source="arXiv:2306.05284",
)

SMOKE = ModelConfig(
    name="musicgen-smoke", arch_type="audio",
    n_layers=2, d_model=192, n_heads=4, n_kv_heads=4, d_ff=384,
    vocab_size=256, frontend="audio", frontend_dim=32, frontend_tokens=8,
    cut_layer=1, remat=False, source="arXiv:2306.05284",
)

ENTRY = ArchEntry(
    arch_id="musicgen-medium", config=CONFIG, smoke=SMOKE, shapes=_FULL,
    skip_notes="long_500k skipped: full quadratic attention.")
