"""Mesh-aware train steps.

``make_sflv3_train_step`` is the paper's technique as a first-class mesh
feature (DESIGN.md §2): every data-parallel group is a virtual hospital —
client (front) segments are stacked along a ``clients`` axis sharded over
``data`` and are NEVER synchronized; the server (middle) segment is shared,
its gradient is the average over clients (SplitFedv3, Algorithm 1).  The
cut-layer activation transfer of the paper is the resharding collective at
the front->middle boundary, measured by the roofline's collective term.

``make_plain_train_step`` is the centralized baseline on the same mesh.

Optional ``compress_boundary`` applies the int8 Pallas link compressor
(beyond-paper optimization, repro.kernels.act_compress).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro import optim as O


def init_sflv3_params(model, key, n_clients: int):
    """Returns ({"fronts": stacked front, "middle": middle}, axes_tree)."""
    kf, km = jax.random.split(key)

    def front_init(k):
        p, _ = model.init(k)
        return p["front"]

    fronts = jax.vmap(front_init)(jax.random.split(kf, n_clients))
    full, axes = model.init(km)
    f_axes = jax.tree.map(lambda a: ("clients",) + tuple(a), axes["front"],
                          is_leaf=lambda v: isinstance(v, tuple))
    return ({"fronts": fronts, "middle": full["middle"]},
            {"fronts": f_axes, "middle": axes["middle"]})


def make_sflv3_train_step(model, opt: O.Optimizer, n_clients: int,
                          compress: bool = False):
    if compress:
        # int8 cut-layer link (beyond-paper; Pallas kernel on TPU, jnp ref
        # under SPMD lowering on CPU — see repro.kernels.act_compress)
        import jax as _jax
        if _jax.default_backend() == "tpu":
            from repro.kernels.act_compress.ops import compress_boundary
        else:
            from repro.kernels.act_compress.ref import roundtrip_ref

            @jax.custom_vjp
            def compress_boundary(x):
                return roundtrip_ref(x)

            compress_boundary.defvjp(
                lambda x: (roundtrip_ref(x), None),
                lambda _, g: (g,))
        boundary_fn = compress_boundary
    else:
        boundary_fn = None

    def loss_fn(params, batch):
        toks = batch["tokens"]
        c = n_clients
        toks = toks.reshape(c, toks.shape[0] // c, toks.shape[1])
        fe = batch.get("frontend_emb")
        if fe is not None:
            fe = fe.reshape(c, fe.shape[0] // c, *fe.shape[1:])

        def per_client(front, t, f):
            b = {"tokens": t}
            if f is not None:
                b["frontend_emb"] = f
            full = {"front": front, "middle": params["middle"]}
            return model.loss(full, b, train=True, boundary_fn=boundary_fn)

        if fe is None:
            losses = jax.vmap(lambda fr, t: per_client(fr, t, None))(
                params["fronts"], toks)
        else:
            losses = jax.vmap(per_client)(params["fronts"], toks, fe)
        return losses.mean()

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        return O.apply_updates(params, updates), opt_state, loss

    return train_step


def make_plain_train_step(model, opt: O.Optimizer):
    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch, train=True))(params)
        updates, opt_state = opt.update(grads, opt_state, params)
        return O.apply_updates(params, updates), opt_state, loss
    return train_step


def get_axes_tree(init_fn, key):
    """Capture the logical-axes tree without materializing params."""
    box = {}

    def f(k):
        p, a = init_fn(k)
        box["a"] = a
        return p

    shapes = jax.eval_shape(f, key)
    return shapes, box["a"]
