"""ShapeDtypeStruct input specs + NamedShardings per (arch × input shape).

Nothing here allocates device memory: FULL configs exist only as abstract
shapes (the shannon/kernels pattern).  ``decode_*`` shapes include the KV/SSM
cache tree; its sharding policy is the production one:

  * decode_32k : cache batch -> data(/pod), cache seq -> model
  * long_500k  : batch==1 (unshardable) -> cache seq over ALL mesh axes
  * sliding-window archs allocate only window-sized ring caches
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES
from repro.launch import mesh as MESH


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg, shape_name: str, mesh):
    sh = INPUT_SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    dp = MESH.dp_axes(mesh)
    batch = {"tokens": sds((b, s + 1), jnp.int32)}
    shardings = {"tokens": NamedSharding(mesh, P(dp, None))}
    if cfg.frontend is not None:
        batch["frontend_emb"] = sds((b, cfg.frontend_tokens,
                                     cfg.frontend_dim), jnp.bfloat16)
        shardings["frontend_emb"] = NamedSharding(mesh, P(dp, None, None))
    return batch, shardings


def prefill_batch_specs(cfg, shape_name: str, mesh):
    sh = INPUT_SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    dp = MESH.dp_axes(mesh)
    batch = {"tokens": sds((b, s), jnp.int32)}
    shardings = {"tokens": NamedSharding(mesh, P(dp, None))}
    if cfg.frontend is not None:
        batch["frontend_emb"] = sds((b, cfg.frontend_tokens,
                                     cfg.frontend_dim), jnp.bfloat16)
        shardings["frontend_emb"] = NamedSharding(mesh, P(dp, None, None))
    return batch, shardings


def decode_token_specs(shape_name: str, mesh):
    sh = INPUT_SHAPES[shape_name]
    b = sh["global_batch"]
    dp = MESH.dp_axes(mesh)
    bspec = dp if b % _axes_size(mesh, dp) == 0 else None
    tokens = sds((b, 1), jnp.int32)
    positions = sds((b, 1), jnp.int32)
    shd = NamedSharding(mesh, P(bspec, None))
    return (tokens, positions), (shd, shd)


def _axes_size(mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= sizes[a]
    return n


def cache_specs(model, shape_name: str, mesh, dtype=jnp.bfloat16,
                as_pspec: bool = False):
    """Abstract cache tree + shardings for a decode shape."""
    sh = INPUT_SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    if model.cfg.frontend is not None:
        s += model.cfg.frontend_tokens          # prefix slots in the cache
    dp = MESH.dp_axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_n = _axes_size(mesh, dp)
    batch_shardable = b % dp_n == 0
    bspec = dp if batch_shardable else None
    # sequence dim: model axis normally; everything when batch unshardable
    seq_axes = ("model",) if batch_shardable else tuple(mesh.axis_names)

    cache_shapes = jax.eval_shape(
        lambda: model.cache_init(b, s, dtype=dtype))

    # run caches carry a leading stacked-layer dim; shared-attn caches do
    # not — distinguish by rank (k/v: 5 vs 4, pos: 3 vs 2, ...)
    _BASE_RANK = {"k": 4, "v": 4, "pos": 2, "conv": 3, "ssm": 4, "index": 0}

    def leaf_spec(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        rank = len(leaf.shape)
        stacked = 1 if rank == _BASE_RANK.get(name, rank) + 1 else 0
        lead = (None,) * stacked

        def fits(dim, axes):
            return dim % _axes_size(mesh, axes) == 0

        def pspec(*entries):             # singleton axis tuples -> bare names
            return P(*(e[0] if isinstance(e, tuple) and len(e) == 1 else e
                       for e in entries))

        if name in ("k", "v"):
            bdim, tdim = leaf.shape[stacked], leaf.shape[stacked + 1]
            tspec = seq_axes if fits(tdim, seq_axes) else None
            return pspec(*lead, bspec, tspec)
        if name == "pos":
            tdim = leaf.shape[stacked + 1]
            tspec = seq_axes if fits(tdim, seq_axes) else None
            return pspec(*lead, bspec, tspec)
        if name == "conv":
            cdim = leaf.shape[-1]
            cspec = ("model",) if cdim % sizes["model"] == 0 else None
            return pspec(*lead, bspec, None, cspec)
        if name == "ssm":
            hdim = leaf.shape[stacked + 1]
            hspec = ("model",) if hdim % sizes["model"] == 0 else None
            return pspec(*lead, bspec, hspec)
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shapes)
    pspecs = jax.tree_util.tree_unflatten(
        treedef, [leaf_spec(path, leaf) for path, leaf in flat])
    if as_pspec:
        return cache_shapes, pspecs
    return cache_shapes, jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda v: isinstance(v, P))
