"""Trip-count-aware analysis of partitioned HLO text.

XLA's ``cost_analysis()`` counts while-loop bodies ONCE (verified
empirically: a 10-iteration scanned matmul reports the flops of one), so
any scan-over-layers program under-reports flops/bytes/collectives by the
layer count.  This module re-derives the three roofline inputs from the
post-SPMD HLO text with loop multipliers propagated through the call graph:

  * flops            — from ``dot`` ops (shape x contracting dims)
  * HBM bytes        — operand+result bytes of top-level (post-fusion) ops:
                       in optimized HLO each non-fused op materializes a
                       buffer, so this approximates HBM traffic
  * collective bytes — result bytes of all-reduce / all-gather /
                       reduce-scatter / all-to-all / collective-permute,
                       with all-reduce counted 2x (reduce + broadcast ring)

Everything is per-device: the module text is the per-partition program.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DT_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
             "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1,
             "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1,
             "u4": 1}

_SHAPE_RE = re.compile(r"(\w[\w\d]*)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->")
_CALLEE_RE = re.compile(
    r"(?:body|condition|to_apply|calls|branch_computations)="
    r"[{]?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)[}]?")
_CONST_RE = re.compile(r"constant\((\d+)\)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_COLL_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def _bytes_of_shapes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


@dataclasses.dataclass
class OpInfo:
    opcode: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    is_fusion_target: bool = False


def parse_hlo(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("//") or line.startswith("HloModule"):
            continue
        if line.endswith("{") and ("->" in line or line.startswith("ENTRY")):
            m = _COMP_HDR.match(line.rstrip("{").strip())
            name = m.group(1) if m else line.split()[0].lstrip("%")
            cur = Computation(name, [])
            comps[name] = cur
            if line.startswith("ENTRY"):
                comps["__entry__"] = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None or "=" not in line:
            continue
        rhs = line.split("=", 1)[1].strip()
        # opcode = first word after the result type(s)
        m = re.search(r"\)?\s*([a-z][\w\-]*)\(", rhs)
        opcode = m.group(1) if m else ""
        cur.ops.append(OpInfo(opcode, line))
    return comps


def _trip_count(cond: Computation) -> int:
    """scan-style conds compare the induction var to a constant bound."""
    consts = []
    for op in cond.ops:
        consts += [int(c) for c in _CONST_RE.findall(op.line)]
    return max(consts) if consts else 1


def _callees(line: str) -> list[str]:
    out = []
    for m in _CALLEE_RE.finditer(line):
        for nm in m.group(1).split(","):
            out.append(nm.strip().lstrip("%"))
    return out


def _dot_flops(line: str, shapes: dict) -> float:
    """2 x prod(result) x prod(contracting dims of lhs)."""
    head = line.split("=", 1)[1].split(" dot(", 1)[0]   # result type(s)
    result_b = _SHAPE_RE.search(head)
    if not result_b:
        return 0.0
    dims = [int(d) for d in result_b.group(2).split(",") if d]
    result_elems = 1
    for d in dims:
        result_elems *= d
    mcd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    # operands are bare %names; resolve their shapes via the module table
    args = line.split(" dot(", 1)[1] if " dot(" in line else ""
    names = re.findall(r"%([\w\.\-]+)", args.split(")")[0])
    k = 1
    if mcd and names and names[0] in shapes:
        lhs_dims = shapes[names[0]]
        for ci in mcd.group(1).split(","):
            if ci != "" and int(ci) < len(lhs_dims):
                k *= lhs_dims[int(ci)]
    return 2.0 * result_elems * k


def _result_shapes(comps: dict) -> dict:
    """Module-wide map: op name -> result dims (single-tensor results)."""
    out = {}
    for comp in comps.values():
        for op in comp.ops:
            lhs, rhs = op.line.split("=", 1)
            name = lhs.strip().lstrip("%").split()[0] if lhs.strip() else ""
            head = rhs.strip()
            if head.startswith("("):
                continue                     # tuple result
            m = _SHAPE_RE.match(head)
            if m and name:
                out[name] = [int(d) for d in m.group(2).split(",") if d]
    return out


def analyze(text: str) -> dict:
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        return {"flops": 0.0, "hbm_bytes": 0.0, "collectives": {}}

    # propagate execution multipliers through the call graph
    mult: dict[str, float] = defaultdict(float)
    seen_fusion_targets: set[str] = set()

    def visit(comp: Computation, m: float):
        mult[comp.name] += m
        for op in comp.ops:
            callees = _callees(op.line)
            if not callees:
                continue
            if op.opcode == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", op.line)
                cm = re.search(r"condition=%?([\w\.\-]+)", op.line)
                if bm and bm.group(1) in comps:
                    tm = re.search(r'known_trip_count.*?"n"\s*:\s*"(\d+)"',
                                   op.line)
                    if tm:
                        trips = int(tm.group(1))
                    elif cm and cm.group(1) in comps:
                        trips = _trip_count(comps[cm.group(1)])
                    else:
                        trips = 1
                    visit(comps[bm.group(1)], m * trips)
            elif op.opcode == "fusion":
                for c in callees:
                    if c in comps:
                        seen_fusion_targets.add(c)
                        visit(comps[c], m)
            else:   # call, conditional, reduce to_apply, sort comparator...
                for c in callees:
                    if c in comps:
                        seen_fusion_targets.add(c) if op.opcode in (
                            "reduce", "sort", "scatter", "map",
                            "reduce-window", "select-and-scatter") else None
                        visit(comps[c], m)

    visit(entry, 1.0)
    shapes = _result_shapes(comps)

    flops = 0.0
    hbm = 0.0
    coll_bytes = {k: 0.0 for k in COLLECTIVES}
    coll_counts = {k: 0.0 for k in COLLECTIVES}
    for name, comp in comps.items():
        if name == "__entry__":
            continue
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        in_fusion = name in seen_fusion_targets
        for op in comp.ops:
            if op.opcode == "dot":
                flops += m * _dot_flops(op.line, shapes)
            for kind in COLLECTIVES:
                if op.opcode == kind:
                    b = _bytes_of_shapes(op.line.split(f" {kind}(")[0]
                                         .split("=", 1)[1])
                    coll_bytes[kind] += m * b * _COLL_FACTOR[kind]
                    coll_counts[kind] += m
                    break
            if not in_fusion and op.opcode not in ("parameter", "constant",
                                                   "tuple",
                                                   "get-tuple-element",
                                                   "bitcast"):
                # top-level op: materialized buffer -> HBM traffic proxy
                hbm += m * _bytes_of_shapes(
                    op.line.split("(", 1)[0])    # result types only

    return {"flops": flops, "hbm_bytes": hbm,
            "collective_bytes": coll_bytes,
            "collective_counts": coll_counts,
            "collective_total": sum(coll_bytes.values())}
