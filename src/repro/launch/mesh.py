"""Production mesh + logical-axis -> PartitionSpec rules.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: (16, 16) over ("data", "model") — 256 v5e
chips.  Multi-pod: (2, 16, 16) over ("pod", "data", "model") — 512 chips;
the pod axis carries pure data parallelism (and FSDP for the very largest
params, see RULES).

Sharding rules map the *logical* axis names attached by every ``*_init`` in
repro.models to mesh axes, with two safety conditions enforced per leaf:
  * a mesh axis is used at most once per PartitionSpec,
  * a dim is sharded only if its size is divisible by the axis size
    (e.g. MiniCPM's deliberately odd 122753 vocab falls back to replicated).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes carrying batch/data parallelism."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


# logical axis -> candidate mesh axes, in preference order.  The first
# candidate that is free (not already used in this spec) and divides the
# dim size wins; otherwise the dim is replicated.
RULES: dict[str, tuple[str, ...]] = {
    "vocab": ("model",),
    "ff": ("model",),
    "heads_flat": ("model",),
    "experts": ("model",),
    "inner_proj": ("model",),
    "inner": ("model",),
    "embed": ("data", "pod"),        # FSDP over data (and pod when free)
    "frontend": (),
    "kv_flat": (),                   # kv heads < model axis: replicate
    "experts_r": (),
    "heads": (),
    "layers": (),                    # scan-stacked layer dim
    "chan": (), "chan_in": (), "classes": (),
    # stacked per-client (hospital) axes: the dedicated 1-D ("hosp",) mesh
    # of core.placement when present, else data parallelism on the
    # production meshes (SFLv3 stacked fronts, engine batch stacks)
    "clients": ("hosp", "data"),
}


def spec_for(axes: tuple, shape: tuple, mesh: Mesh) -> P:
    used: set[str] = set()
    out = []
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for ax_name, dim in zip(axes, shape):
        choice = None
        if ax_name is not None:
            for cand in RULES.get(ax_name, ()):
                if cand in sizes and cand not in used and \
                        dim % sizes[cand] == 0:
                    choice = cand
                    used.add(cand)
                    break
        out.append(choice)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_shardings(axes_tree: Any, shape_tree: Any, mesh: Mesh):
    """Build a NamedSharding pytree from the logical-axes tree produced by
    model init and a matching ShapeDtypeStruct tree."""
    is_axes_leaf = lambda v: isinstance(v, tuple) and all(
        isinstance(x, (str, type(None))) for x in v)

    def one(axes, sds):
        return NamedSharding(mesh, spec_for(axes, sds.shape, mesh))

    return jax.tree.map(one, axes_tree, shape_tree, is_leaf=is_axes_leaf)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def batch_sharding(mesh: Mesh, rank: int, batch_dim: int = 0):
    spec = [None] * rank
    spec[batch_dim] = dp_axes(mesh)
    return NamedSharding(mesh, P(*spec))
