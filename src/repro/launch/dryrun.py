import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e) + roofline source data (deliverable g).

For every (architecture × input shape × mesh) combination this lowers and
compiles the appropriate step function against pure ShapeDtypeStructs —
proving the distribution config is coherent without hardware — and records
memory analysis, HLO FLOPs/bytes and per-kind collective bytes into
``benchmarks/results/dryrun_<arch>_<shape>_<mesh>.json``.

  train_4k              -> SplitFedv3 train_step (the paper's technique;
                           virtual hospitals == data-parallel groups)
  prefill_32k           -> prefill_step (cache built in-program)
  decode_32k/long_500k  -> decode_step (one token vs a seq_len cache)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--out benchmarks/results]
"""

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim as O
from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import REGISTRY
from repro.launch import mesh as MESH
from repro.launch import specs as SPECS
from repro.launch.train import (get_axes_tree, init_sflv3_params,
                                make_sflv3_train_step)
from repro.models.transformer import TransformerLM
from repro.serving.engine import make_decode_step, make_prefill_step

# Per-chip/-host hardware peaks the roofline terms divide by.  Keyed so a
# CPU smoke run can label its roofline honestly instead of pretending its
# numbers sit on a TPU's ceilings; ``cpu_host`` is a nominal modern server
# (AVX-512 f32 FMA, dual-socket DDR5, 100 GbE interconnect).
HW_TABLE = {
    "tpu_v5e": {"peak_flops": 197e12, "hbm_bw": 819e9, "ici_bw": 50e9},
    "cpu_host": {"peak_flops": 2e12, "hbm_bw": 200e9, "ici_bw": 12.5e9},
}
HW = HW_TABLE["tpu_v5e"]              # historical default (TPU v5e, per chip)


def default_hw() -> str:
    """The HW_TABLE key matching the current jax backend."""
    import jax
    return "tpu_v5e" if jax.default_backend() == "tpu" else "cpu_host"

_DT_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
             "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1,
             "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"(\w[\w\-]*)\[([0-9,]*)\][^=]*?\s(all-reduce|all-gather|reduce-scatter"
    r"|all-to-all|collective-permute)\(")
_SHAPE_RE = re.compile(r"(\w[\w\d]*)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Per-kind result-bytes of every collective op in the partitioned HLO."""
    out = {k: 0 for k in ("all-reduce", "all-gather", "reduce-scatter",
                          "all-to-all", "collective-permute")}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        for kind in out:
            tag = f" {kind}("
            if tag in line and "=" in line:
                lhs = line.split(tag)[0]
                # sum all result tensors (tuple results list each operand)
                rhs = lhs.split("=")[-1]
                for dt, dims in _SHAPE_RE.findall(rhs):
                    if dt not in _DT_BYTES:
                        continue
                    n = 1
                    for d in dims.split(","):
                        if d:
                            n *= int(d)
                    out[kind] += n * _DT_BYTES[dt]
                counts[kind] += 1
                break
    out["counts"] = counts
    return out


def _opt_shardings(opt_shapes, param_sh, mesh):
    return {"step": MESH.replicated(mesh),
            "mu": param_sh, "nu": param_sh}


def _apply_variant(cfg, variant: dict):
    import dataclasses
    fields = {k: v for k, v in (variant or {}).items()
              if k in {f.name for f in dataclasses.fields(cfg)}}
    return dataclasses.replace(cfg, **fields) if fields else cfg


def build_train(entry, shape_name, mesh, variant=None):
    variant = variant or {}
    cfg = _apply_variant(entry.config, variant)
    model = TransformerLM.build(cfg)
    n_clients = 1
    for a in MESH.dp_axes(mesh):
        n_clients *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    opt = O.adam(1e-4, state_dtype=jnp.bfloat16)
    step = make_sflv3_train_step(model, opt, n_clients,
                                 compress=variant.get("compress", False))

    param_shapes, axes = get_axes_tree(
        lambda k: init_sflv3_params(model, k, n_clients), jax.random.key(0))
    param_sh = MESH.tree_shardings(axes, param_shapes, mesh)
    opt_shapes = jax.eval_shape(opt.init, param_shapes)
    opt_sh = _opt_shardings(opt_shapes, param_sh, mesh)
    batch, batch_sh = SPECS.train_batch_specs(cfg, shape_name, mesh)
    args = (param_shapes, opt_shapes, batch)
    in_sh = (param_sh, opt_sh, batch_sh)
    out_sh = (param_sh, opt_sh, MESH.replicated(mesh))
    return step, args, in_sh, out_sh


def build_prefill(entry, shape_name, mesh, variant=None):
    cfg = _apply_variant(entry.config, variant or {})
    model = TransformerLM.build(cfg)
    s = INPUT_SHAPES[shape_name]["seq_len"]
    if cfg.frontend is not None:
        s += cfg.frontend_tokens
    step = make_prefill_step(model, max_len=s)
    param_shapes, axes = get_axes_tree(model.init, jax.random.key(0))
    param_sh = MESH.tree_shardings(axes, param_shapes, mesh)
    batch, batch_sh = SPECS.prefill_batch_specs(cfg, shape_name, mesh)
    _, cache_sh = SPECS.cache_specs(model, shape_name, mesh)
    b = INPUT_SHAPES[shape_name]["global_batch"]
    logits_sh = NamedSharding(mesh, P(MESH.dp_axes(mesh), "model"
                                      if cfg.vocab_size % 16 == 0 else None))
    args = (param_shapes, batch)
    return step, args, (param_sh, batch_sh), (logits_sh, cache_sh)


def build_decode(entry, shape_name, mesh, variant=None):
    cfg = _apply_variant(entry.config, variant or {})
    model = TransformerLM.build(cfg)
    step = make_decode_step(model)
    param_shapes, axes = get_axes_tree(model.init, jax.random.key(0))
    param_sh = MESH.tree_shardings(axes, param_shapes, mesh)
    cache_shapes, cache_sh = SPECS.cache_specs(model, shape_name, mesh)
    (tokens, positions), (tok_sh, pos_sh) = SPECS.decode_token_specs(
        shape_name, mesh)
    b = INPUT_SHAPES[shape_name]["global_batch"]
    dp = MESH.dp_axes(mesh)
    bspec = dp if b % SPECS._axes_size(mesh, dp) == 0 else None
    logits_sh = NamedSharding(mesh, P(bspec, "model"
                                      if cfg.vocab_size % 16 == 0 else None))
    args = (param_shapes, cache_shapes, tokens, positions)
    return step, args, (param_sh, cache_sh, tok_sh, pos_sh), \
        (logits_sh, cache_sh)


def run_combo(arch_id: str, shape_name: str, multi_pod: bool,
              save_hlo: str | None = None, variant: dict | None = None) -> dict:
    entry = REGISTRY[arch_id]
    mesh_name = "multi" if multi_pod else "single"
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "status": "skipped", "notes": "", "variant": variant or {}}
    if shape_name not in entry.shapes:
        rec["notes"] = entry.skip_notes
        return rec
    mesh = MESH.make_production_mesh(multi_pod=multi_pod)
    kind = INPUT_SHAPES[shape_name]["kind"]
    t0 = time.time()
    try:
        with jax.default_device(jax.devices("cpu")[0]):
            if kind == "train":
                step, args, in_sh, out_sh = build_train(entry, shape_name,
                                                        mesh, variant)
            elif kind == "prefill":
                step, args, in_sh, out_sh = build_prefill(entry, shape_name,
                                                          mesh, variant)
            else:
                step, args, in_sh, out_sh = build_decode(entry, shape_name,
                                                         mesh, variant)
            with mesh:
                lowered = jax.jit(step, in_shardings=in_sh,
                                  out_shardings=out_sh).lower(*args)
                rec["lower_s"] = round(time.time() - t0, 1)
                t1 = time.time()
                compiled = lowered.compile()
                rec["compile_s"] = round(time.time() - t1, 1)

            mem = compiled.memory_analysis()
            if mem is not None:
                for k in ("temp_size_in_bytes", "argument_size_in_bytes",
                          "output_size_in_bytes", "alias_size_in_bytes",
                          "generated_code_size_in_bytes"):
                    rec[k] = int(getattr(mem, k, 0) or 0)
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0]
            # raw cost_analysis counts while-loop bodies ONCE (verified);
            # hlo_analysis re-derives costs with trip-count multipliers.
            rec["hlo_flops_raw"] = float(cost.get("flops", 0.0))
            rec["hlo_bytes_raw"] = float(cost.get("bytes accessed", 0.0))
            txt = compiled.as_text()
            from repro.launch import hlo_analysis as HA
            ana = HA.analyze(txt)
            rec["hlo_flops"] = max(ana["flops"], rec["hlo_flops_raw"])
            rec["hlo_bytes"] = max(ana["hbm_bytes"], rec["hlo_bytes_raw"])
            rec["collectives"] = {**{k: int(v) for k, v in
                                     ana["collective_bytes"].items()},
                                  "counts": {k: int(v) for k, v in
                                             ana["collective_counts"].items()}}
            if save_hlo:
                with open(save_hlo, "w") as f:
                    f.write(txt)
            del txt
            rec["status"] = "ok"
    except Exception as e:  # a failure here is a bug in the system
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def roofline_terms(rec: dict, mesh_chips: int, hw=None) -> dict:
    """The three roofline terms in seconds (single-pod table; DESIGN.md §5).
    cost_analysis FLOPs/bytes are per-device program numbers on the
    partitioned module; collective bytes are per-device link traffic.
    ``hw``: an ``HW_TABLE`` key or peaks dict (default: TPU v5e)."""
    if hw is None:
        hw = HW
    elif isinstance(hw, str):
        hw = HW_TABLE[hw]
    coll = rec.get("collectives", {})
    coll_b = sum(v for k, v in coll.items() if k != "counts")
    t_compute = rec.get("hlo_flops", 0.0) / hw["peak_flops"]
    t_memory = rec.get("hlo_bytes", 0.0) / hw["hbm_bw"]
    t_coll = coll_b / hw["ici_bw"]
    dom = max((("compute", t_compute), ("memory", t_memory),
               ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {"t_compute": t_compute, "t_memory": t_memory,
            "t_collective": t_coll, "dominant": dom,
            "collective_bytes": coll_b}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="benchmarks/results")
    ap.add_argument("--tag", default="", help="suffix for variant runs")
    ap.add_argument("--variant", default=None,
                    help='JSON config overrides, e.g. '
                         '\'{"vocab_pad_to": 256, "compress": true}\'')
    args = ap.parse_args()
    variant = json.loads(args.variant) if args.variant else None

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    archs = [args.arch] if args.arch else list(REGISTRY)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)

    for aid in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "multi" if mp else "single"
                tag = f"_{args.tag}" if args.tag else ""
                path = os.path.join(
                    args.out, f"dryrun_{aid}_{shape}_{mesh_name}{tag}.json")
                if os.path.exists(path):
                    rec = json.load(open(path))
                    if rec.get("status") == "ok":
                        print(f"[cached] {aid} {shape} {mesh_name}")
                        continue
                rec = run_combo(aid, shape, mp, variant=variant)
                if rec["status"] == "ok":
                    rec["roofline"] = roofline_terms(
                        rec, 512 if mp else 256)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                msg = rec.get("error", "") or rec.get("notes", "")
                print(f"[{rec['status']:7s}] {aid:24s} {shape:12s} "
                      f"{mesh_name:6s} {rec.get('total_s', 0):7.1f}s  {msg}",
                      flush=True)


if __name__ == "__main__":
    main()
